//! The per-vertex GHS automaton — a faithful implementation of the response
//! procedures of Gallager, Humblet, Spira (TOPLAS 1983), extended with the
//! paper's forest halt (a fragment core that sees `Report(∞)` on both sides
//! stops; disconnected inputs yield a minimum spanning forest).
//!
//! Why the paper's §3.4 Test-queue relaxation is safe (and implemented
//! as-is here): while a vertex has an outstanding `Test`, it cannot report,
//! so its fragment's search cannot complete, so its fragment can neither
//! merge nor be the sender of any later message on the tested edge — i.e.
//! on any edge direction a `Test` is never followed by another message it
//! could be reordered with. Delaying Tests in a separate queue therefore
//! preserves per-edge-direction FIFO semantics for every ordering the
//! algorithm relies on. (Messages of *other* vertices routed through the
//! same rank pair may overtake a Test; GHS never requires cross-edge
//! ordering.)

use crate::ghs::message::{Message, Payload};
use crate::ghs::rank::{RankState, NIL};
use crate::obs::trace::EventKind;
use crate::ghs::types::{EdgeState, Level, VertexState, MAX_WIRE_LEVEL};
use crate::ghs::weight::{EdgeWeight, FragmentId};
use crate::graph::VertexId;

/// Result of processing one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Fully processed.
    Done,
    /// Cannot be processed yet; re-queue ("place on end of queue").
    Postponed,
}

impl RankState {
    /// Wake up every local Sleeping vertex (the engine does this in each
    /// rank's first iteration; the original GHS also allows wakeup on first
    /// message receipt, which cannot occur under this schedule).
    pub fn wakeup_all(&mut self) {
        for row in 0..self.csr.rows() {
            if self.vars[row as usize].sn == VertexState::Sleeping {
                let v = self.csr.vertex_of(row);
                self.wakeup(v);
            }
        }
    }

    /// GHS procedure `wakeup`: mark the minimum-weight adjacent edge as a
    /// Branch and try to connect over it at level 0.
    fn wakeup(&mut self, v: VertexId) {
        // The weight-sorted adjacency order makes the minimum edge the
        // first entry of the row's sorted segment.
        let best = self.csr.row_range(v).start..self.csr.row_range(v).end;
        let best = if best.is_empty() {
            None
        } else {
            Some(self.sorted_adj[best.start] as usize)
        };
        let vars = self.vars_mut(v);
        debug_assert_eq!(vars.sn, VertexState::Sleeping);
        vars.ln = 0;
        vars.sn = VertexState::Found;
        vars.find_count = 0;
        match best {
            None => {
                // Isolated vertex: a complete single-vertex component.
                vars.halted = true;
            }
            Some(m) => {
                self.mark_branch(v, m);
                self.send(v, m, Payload::Connect { level: 0 });
            }
        }
    }

    /// Dispatch one message to its destination vertex's automaton.
    pub fn handle(&mut self, msg: Message) -> Outcome {
        let v = msg.dst;
        debug_assert!(self.csr.owns(v), "message routed to wrong rank");
        // NOTE on a rejected optimization (kept as documentation): one
        // could postpone a higher-level Test *before* the §3.3 edge lookup
        // (`if level > LN { return Postponed }` here), making retries
        // nearly free. We implemented and measured it: wall-clock gain was
        // <5 %, but it ERASES the paper's §3.4 phenomenon — with cheap
        // retries the separate Test queue no longer buys the ~2× the paper
        // (and our SSCA2 ablation) attributes to it, because that gain
        // comes exactly from not re-paying lookup+dispatch per retry. The
        // paper's implementation reprocesses messages fully per attempt
        // ("Some messages are processed repeatedly"), so we keep that
        // semantics: every attempt pays the full lookup.
        let j = self
            .lookup
            .find(&self.csr, msg.src, v, &mut self.lookup_stats)
            .unwrap_or_else(|| panic!("message over non-existent edge {} -> {}", msg.src, v));
        match msg.payload {
            Payload::Connect { level } => self.on_connect(v, j, level),
            Payload::Initiate { level, fragment, state } => {
                self.on_initiate(v, j, level, fragment, state);
                Outcome::Done
            }
            Payload::Test { level, fragment } => self.on_test(v, j, level, fragment),
            Payload::Accept => {
                self.on_accept(v, j);
                Outcome::Done
            }
            Payload::Reject => {
                self.on_reject(v, j);
                Outcome::Done
            }
            Payload::Report { best } => self.on_report(v, j, best),
            Payload::ChangeCore => {
                self.change_core(v);
                Outcome::Done
            }
        }
    }

    /// GHS (3): response to Connect(L) on edge j.
    fn on_connect(&mut self, v: VertexId, j: usize, l: Level) -> Outcome {
        let (ln, fragment, sn) = {
            let vars = self.vars_of(v);
            (vars.ln, vars.fragment, vars.sn)
        };
        if l < ln {
            // Absorb the lower-level fragment: j becomes a Branch and the
            // absorbed subtree receives our (level, identity, state).
            if self.trace.is_some() {
                let nbr = self.csr.col(j);
                self.trace_ev(EventKind::FragmentAbsorb, v as u64, nbr as u64, ln as u64);
            }
            self.mark_branch(v, j);
            self.send(v, j, Payload::Initiate { level: ln, fragment, state: sn });
            if sn == VertexState::Find {
                self.vars_mut(v).find_count += 1;
            }
            Outcome::Done
        } else if self.edge_state[j] == EdgeState::Basic {
            // Equal (or higher) level over a Basic edge: cannot answer yet.
            Outcome::Postponed
        } else {
            // Equal level over a Branch edge: both sides connected over j —
            // merge. j becomes the core of a level L+1 fragment whose
            // identity is the weight of j.
            debug_assert_eq!(self.edge_state[j], EdgeState::Branch, "Connect over Rejected edge");
            debug_assert!(ln < MAX_WIRE_LEVEL, "fragment level overflows 8-bit wire field");
            let fid: FragmentId = self.edge_weight(v, j);
            if self.trace.is_some() {
                // Fires at both core endpoints; the timeline replay
                // counts unions, so the double emission is by design.
                let nbr = self.csr.col(j);
                self.trace_ev(EventKind::FragmentMerge, v as u64, nbr as u64, (ln + 1) as u64);
            }
            self.send(
                v,
                j,
                Payload::Initiate { level: ln + 1, fragment: fid, state: VertexState::Find },
            );
            Outcome::Done
        }
    }

    /// GHS (4): response to Initiate(L, F, S) on edge j.
    fn on_initiate(&mut self, v: VertexId, j: usize, l: Level, f: FragmentId, s: VertexState) {
        if self.trace.is_some() {
            let old = self.vars_of(v).ln;
            self.trace_ev(EventKind::FragmentAdopt, v as u64, l as u64, old as u64);
        }
        {
            let vars = self.vars_mut(v);
            vars.ln = l;
            vars.fragment = f;
            vars.sn = s;
            vars.in_branch = j as u32;
            vars.best_edge = NIL;
            vars.best_wt = EdgeWeight::infinity();
        }
        // Propagate down every other Branch edge (the maintained per-row
        // branch list avoids rescanning the whole adjacency row).
        let row = self.csr.row_of(v);
        let mut n_children = 0i32;
        for bi in 0..self.branch_list[row].len() {
            let i = self.branch_list[row][bi] as usize;
            if i != j {
                debug_assert_eq!(self.edge_state[i], EdgeState::Branch);
                self.send(v, i, Payload::Initiate { level: l, fragment: f, state: s });
                n_children += 1;
            }
        }
        if s == VertexState::Find {
            self.vars_mut(v).find_count += n_children;
            self.test(v);
        }
    }

    /// GHS (5): procedure test — probe the minimum-weight Basic edge, or
    /// report if none remain.
    ///
    /// Uses the per-row weight-sorted order with a monotone cursor: edge
    /// states never revert to Basic, so entries skipped once stay
    /// skippable and the scan is O(degree) amortized over the whole run.
    fn test(&mut self, v: VertexId) {
        let range = self.csr.row_range(v);
        let row = self.csr.row_of(v);
        let mut cur = self.vars[row].cursor as usize;
        let mut best: Option<usize> = None;
        while range.start + cur < range.end {
            let i = self.sorted_adj[range.start + cur] as usize;
            if self.edge_state[i] == EdgeState::Basic {
                best = Some(i);
                break;
            }
            cur += 1;
        }
        self.vars[row].cursor = cur as u32;
        match best {
            Some(i) => {
                let (ln, fragment) = {
                    let vars = self.vars_mut(v);
                    vars.test_edge = i as u32;
                    (vars.ln, vars.fragment)
                };
                self.send(v, i, Payload::Test { level: ln, fragment });
            }
            None => {
                self.vars_mut(v).test_edge = NIL;
                self.report(v);
            }
        }
    }

    /// GHS (6): response to Test(L, F) on edge j.
    fn on_test(&mut self, v: VertexId, j: usize, l: Level, f: FragmentId) -> Outcome {
        let (ln, fragment) = {
            let vars = self.vars_of(v);
            (vars.ln, vars.fragment)
        };
        if l > ln {
            return Outcome::Postponed;
        }
        if f != fragment {
            self.send(v, j, Payload::Accept);
            return Outcome::Done;
        }
        // Same fragment: the edge is internal.
        if self.edge_state[j] == EdgeState::Basic {
            self.edge_state[j] = EdgeState::Rejected;
        }
        if self.vars_of(v).test_edge != j as u32 {
            self.send(v, j, Payload::Reject);
        } else {
            // Our own probe of this edge is moot; move to the next one.
            self.test(v);
        }
        Outcome::Done
    }

    /// GHS (7): response to Accept on edge j.
    fn on_accept(&mut self, v: VertexId, j: usize) {
        let w = self.edge_weight(v, j);
        {
            let vars = self.vars_mut(v);
            vars.test_edge = NIL;
            if w < vars.best_wt {
                vars.best_edge = j as u32;
                vars.best_wt = w;
            }
        }
        self.report(v);
    }

    /// GHS (8): response to Reject on edge j.
    fn on_reject(&mut self, v: VertexId, j: usize) {
        if self.edge_state[j] == EdgeState::Basic {
            self.edge_state[j] = EdgeState::Rejected;
        }
        self.test(v);
    }

    /// GHS (9): procedure report — once all subtree Reports arrived and the
    /// local probe finished, report the best weight towards the core.
    fn report(&mut self, v: VertexId) {
        let (ready, in_branch, best_wt) = {
            let vars = self.vars_of(v);
            (
                vars.find_count == 0 && vars.test_edge == NIL,
                vars.in_branch,
                vars.best_wt,
            )
        };
        if ready {
            self.vars_mut(v).sn = VertexState::Found;
            debug_assert_ne!(in_branch, NIL, "report before any Initiate");
            self.send(v, in_branch as usize, Payload::Report { best: best_wt });
        }
    }

    /// GHS (10): response to Report(w) on edge j.
    fn on_report(&mut self, v: VertexId, j: usize, w: EdgeWeight) -> Outcome {
        let in_branch = self.vars_of(v).in_branch;
        if j as u32 != in_branch {
            // A child subtree reports.
            {
                let vars = self.vars_mut(v);
                vars.find_count -= 1;
                debug_assert!(vars.find_count >= 0, "find_count underflow");
                if w < vars.best_wt {
                    vars.best_wt = w;
                    vars.best_edge = j as u32;
                }
            }
            self.report(v);
            Outcome::Done
        } else {
            // The other core half reports.
            let (sn, best_wt) = {
                let vars = self.vars_of(v);
                (vars.sn, vars.best_wt)
            };
            if sn == VertexState::Find {
                return Outcome::Postponed;
            }
            if w > best_wt {
                self.change_core(v);
            } else if w == best_wt && w.is_infinite() {
                // Forest halt: no outgoing edge on either side — this
                // fragment spans its entire connected component.
                self.vars_mut(v).halted = true;
                self.halts += 1;
                if self.trace.is_some() {
                    let ln = self.vars_of(v).ln;
                    self.trace_ev(EventKind::Halt, v as u64, 0, ln as u64);
                }
            }
            // w < best_wt: the other core vertex performs change_core.
            Outcome::Done
        }
    }

    /// GHS (11): procedure change_core — forward towards the fragment's
    /// minimum outgoing edge; the vertex adjacent to it sends Connect.
    fn change_core(&mut self, v: VertexId) {
        let best_edge = self.vars_of(v).best_edge;
        debug_assert_ne!(best_edge, NIL, "change_core without a best edge");
        let be = best_edge as usize;
        if self.edge_state[be] == EdgeState::Branch {
            self.send(v, be, Payload::ChangeCore);
        } else {
            let ln = self.vars_of(v).ln;
            self.send(v, be, Payload::Connect { level: ln });
            self.mark_branch(v, be);
        }
    }
}

#[cfg(test)]
mod tests {
    //! Unit tests drive a single-rank RankState by hand; full-protocol
    //! correctness (GHS == Kruskal over thousands of graphs) lives in
    //! `engine::tests` and `rust/tests/`.
    use super::*;
    use crate::ghs::config::GhsConfig;
    use crate::ghs::wire::IdentityCodec;
    use crate::graph::partition::Partition;
    use crate::graph::EdgeList;

    fn one_rank(g: &EdgeList) -> RankState {
        let part = Partition::block(g.n_vertices, 1);
        let cfg = GhsConfig { n_ranks: 1, ..GhsConfig::default() };
        RankState::new(0, g, part, &cfg, IdentityCodec::SpecialId)
    }

    #[test]
    fn wakeup_marks_min_edge_branch_and_connects() {
        let mut g = EdgeList::with_vertices(3);
        g.push(0, 1, 0.9);
        g.push(0, 2, 0.1); // min edge of vertex 0
        let mut r = one_rank(&g);
        r.wakeup_all();
        // Vertex 0's min edge (to 2) must be Branch.
        let adj0: Vec<_> = r.csr.neighbours(0).collect();
        for (i, nbr, _) in adj0 {
            let expect = if nbr == 2 { EdgeState::Branch } else { EdgeState::Basic };
            assert_eq!(r.edge_state[i], expect);
        }
        // All three vertices sent Connect(0).
        assert_eq!(r.sent_counts.connect, 3);
        // All local: queued in own queues.
        assert_eq!(r.queues.total_len(), 3);
        for v in 0..3 {
            assert_eq!(r.vars_of(v).sn, VertexState::Found);
            assert_eq!(r.vars_of(v).ln, 0);
        }
    }

    #[test]
    fn isolated_vertex_halts_immediately() {
        let mut g = EdgeList::with_vertices(3);
        g.push(0, 1, 0.5);
        let mut r = one_rank(&g);
        r.wakeup_all();
        assert!(r.vars_of(2).halted, "degree-0 vertex is its own component");
        assert!(!r.vars_of(0).halted);
    }

    #[test]
    fn two_vertices_merge_to_level_1() {
        // Smallest possible merge: both vertices pick the single edge,
        // exchange Connect(0), then Initiate(1, w, Find).
        let mut g = EdgeList::with_vertices(2);
        g.push(0, 1, 0.5);
        let mut r = one_rank(&g);
        r.wakeup_all();
        // Drain queues until silent, driving the stash like the engines:
        // postponed messages re-arm after any completed message.
        let mut guard = 0;
        while r.queues.total_len() > 0 {
            // A stranded stash is a deadlock; report it structurally (the
            // same per-rank detail the async scheduler's deadlock error
            // carries) instead of dying on an opaque expect.
            let msg = match r.queues.pop_main().or_else(|| r.queues.pop_test()) {
                Some(m) => m,
                None => panic!(
                    "active queues empty but stash stranded (deadlock): {}",
                    r.stranded_report().unwrap_or_else(|| "no stranded work".into())
                ),
            };
            if r.handle(msg) == Outcome::Postponed {
                r.queues.postpone(msg);
            } else {
                r.queues.note_done();
            }
            guard += 1;
            assert!(guard < 100, "no convergence");
        }
        for v in 0..2 {
            assert_eq!(r.vars_of(v).ln, 1, "merged to level 1");
            assert_eq!(r.vars_of(v).fragment, EdgeWeight::new(0.5, 0, 1));
        }
        // Both core vertices halted with no outgoing edges.
        assert_eq!(r.halts, 2);
    }

    #[test]
    fn connect_equal_level_over_basic_edge_postpones() {
        let mut g = EdgeList::with_vertices(3);
        g.push(0, 1, 0.1);
        g.push(1, 2, 0.2);
        g.push(0, 2, 0.3);
        let mut r = one_rank(&g);
        r.wakeup_all();
        // Hand-craft: vertex 2 receives Connect(0) from 0 over edge (0,2),
        // which is Basic at 2, and 2 is at level 0 -> postpone.
        let msg = Message::new(0, 2, Payload::Connect { level: 0 });
        assert_eq!(r.handle(msg), Outcome::Postponed);
    }

    #[test]
    fn test_message_from_higher_level_postpones() {
        let mut g = EdgeList::with_vertices(2);
        g.push(0, 1, 0.5);
        let mut r = one_rank(&g);
        r.wakeup_all();
        let f = EdgeWeight::new(0.9, 0, 1);
        let msg = Message::new(0, 1, Payload::Test { level: 5, fragment: f });
        assert_eq!(r.handle(msg), Outcome::Postponed);
    }

    #[test]
    fn test_from_other_fragment_accepts() {
        let mut g = EdgeList::with_vertices(2);
        g.push(0, 1, 0.5);
        let mut r = one_rank(&g);
        r.wakeup_all();
        // Level 0, different fragment id -> Accept.
        let f = EdgeWeight::new(0.123, 0, 1);
        let before = r.sent_counts.accept;
        let msg = Message::new(0, 1, Payload::Test { level: 0, fragment: f });
        assert_eq!(r.handle(msg), Outcome::Done);
        assert_eq!(r.sent_counts.accept, before + 1);
    }
}
