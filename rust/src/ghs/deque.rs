//! Chase–Lev work-stealing deque of task ids (the async scheduler's
//! per-worker run queue).
//!
//! One deque per pool worker. The owning worker pushes and pops at the
//! *bottom* (LIFO — a just-woken task's mailbox is still hot in cache);
//! every other worker steals from the *top* (FIFO — thieves take the
//! oldest task, the one the owner is furthest from revisiting). This is
//! the classic Chase–Lev layout (SPAA'05), with the SeqCst fences of the
//! Lê–Pop–Cohen–Nardelli C11 formulation.
//!
//! Two simplifications relative to the general algorithm, both bought by
//! scheduler invariants:
//!
//! * **No growth.** A task is on at most one deque at a time (the
//!   `IDLE/READY/RUNNING/WOKEN` state machine enqueues a task only on the
//!   `IDLE → READY` and requeue transitions, and it leaves the deque
//!   before running), so a deque never holds more than the total task
//!   count. Constructed with capacity > that bound, `push` can never lap
//!   `top` — no resizing, and no ABA on slot reuse: a slot read by a
//!   stealer cannot be overwritten until the stealer's `top` CAS has
//!   settled.
//! * **No unsafe.** Items are bare `u32` task ids stored in `AtomicU32`
//!   slots, so the racy buffer reads of the textbook version (the reason
//!   it needs `UnsafeCell`) are plain relaxed atomic loads here; the `top`
//!   CAS still decides which contender owns the value it read.

use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};

/// Outcome of a steal attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Steal {
    /// Stole the oldest task.
    Success(u32),
    /// The deque was observed empty.
    Empty,
    /// Lost a race with the owner or another thief; retrying immediately
    /// is allowed (the loser made the winner's progress possible).
    Retry,
}

/// A fixed-capacity Chase–Lev deque of `u32` task ids.
#[derive(Debug)]
pub struct WorkDeque {
    /// Next slot to steal from (only ever incremented, by successful
    /// steals and by the owner's last-element pop).
    top: AtomicI64,
    /// Next slot the owner pushes to (owner-written; thieves only read).
    bottom: AtomicI64,
    /// `capacity - 1` (capacity is a power of two).
    mask: i64,
    buf: Box<[AtomicU32]>,
}

impl WorkDeque {
    /// A deque holding at most `max_items` concurrently. Capacity is
    /// rounded to the next power of two *strictly above* `max_items`, so
    /// the no-growth / no-ABA argument in the module docs holds.
    pub fn new(max_items: usize) -> Self {
        let cap = (max_items + 1).next_power_of_two();
        Self {
            top: AtomicI64::new(0),
            bottom: AtomicI64::new(0),
            mask: cap as i64 - 1,
            buf: (0..cap).map(|_| AtomicU32::new(0)).collect(),
        }
    }

    /// Owner only: push a task at the bottom.
    pub fn push(&self, task: u32) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        debug_assert!(b - t <= self.mask, "deque over capacity: a task was enqueued twice");
        self.buf[(b & self.mask) as usize].store(task, Ordering::Relaxed);
        // Publish the slot before the new bottom becomes visible to thieves.
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner only: pop the most recently pushed task (LIFO).
    pub fn pop(&self) -> Option<u32> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        // The store above must be visible before we read `top`, and
        // symmetrically for thieves (their SeqCst CAS) — the crux of
        // Chase–Lev.
        std::sync::atomic::fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let task = self.buf[(b & self.mask) as usize].load(Ordering::Relaxed);
            if t == b {
                // Last element: race the thieves for it via `top`.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                return won.then_some(task);
            }
            Some(task)
        } else {
            // Already empty; undo the speculative decrement.
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Any thread: steal the oldest task (FIFO).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        std::sync::atomic::fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        let task = self.buf[(t & self.mask) as usize].load(Ordering::Relaxed);
        // The CAS decides whether the value we read was ours to take; the
        // no-lap capacity bound guarantees the slot was not overwritten in
        // between (see module docs).
        if self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            Steal::Success(task)
        } else {
            Steal::Retry
        }
    }

    /// Racy emptiness hint (used by parking workers to decide whether a
    /// re-scan is worthwhile; never used for correctness decisions).
    pub fn is_empty(&self) -> bool {
        let t = self.top.load(Ordering::Relaxed);
        let b = self.bottom.load(Ordering::Relaxed);
        b <= t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn lifo_for_owner_fifo_for_thief() {
        let d = WorkDeque::new(8);
        for task in 0..4 {
            d.push(task);
        }
        assert_eq!(d.steal(), Steal::Success(0), "thief takes the oldest");
        assert_eq!(d.pop(), Some(3), "owner takes the newest");
        assert_eq!(d.steal(), Steal::Success(1));
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), None);
        assert_eq!(d.steal(), Steal::Empty);
        assert!(d.is_empty());
    }

    #[test]
    fn capacity_rounds_strictly_above_bound() {
        // max_items tasks plus the owner's in-flight push must fit without
        // wrapping onto unconsumed slots.
        for max in [1usize, 7, 8, 4096] {
            let d = WorkDeque::new(max);
            assert!(d.mask as usize + 1 > max, "capacity must exceed max_items");
            for task in 0..max as u32 {
                d.push(task);
            }
            for task in (0..max as u32).rev() {
                assert_eq!(d.pop(), Some(task));
            }
        }
    }

    /// Owner-pop vs steal race: an owner popping LIFO and thieves stealing
    /// FIFO concurrently must hand out every task exactly once — no loss,
    /// no duplication — across seeded schedules (the seed varies the
    /// owner's push/pop interleaving).
    #[test]
    fn concurrent_owner_and_thieves_partition_the_tasks() {
        for seed in [1u64, 42, 0xC0FFEE] {
            let n: u32 = 20_000;
            let d = Arc::new(WorkDeque::new(n as usize));
            let taken: Arc<Vec<AtomicU64>> =
                Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let thieves: Vec<_> = (0..3)
                .map(|_| {
                    let d = Arc::clone(&d);
                    let taken = Arc::clone(&taken);
                    let stop = Arc::clone(&stop);
                    std::thread::spawn(move || {
                        let mut got = 0u64;
                        while !stop.load(Ordering::Acquire) {
                            match d.steal() {
                                Steal::Success(t) => {
                                    taken[t as usize].fetch_add(1, Ordering::Relaxed);
                                    got += 1;
                                }
                                Steal::Retry => {}
                                Steal::Empty => std::thread::yield_now(),
                            }
                        }
                        // Drain whatever the owner left behind.
                        loop {
                            match d.steal() {
                                Steal::Success(t) => {
                                    taken[t as usize].fetch_add(1, Ordering::Relaxed);
                                    got += 1;
                                }
                                Steal::Retry => {}
                                Steal::Empty => return got,
                            }
                        }
                    })
                })
                .collect();
            // Owner: seeded mix of pushes and LIFO pops.
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let mut next = 0u32;
            let mut owner_got = 0u64;
            while next < n {
                let burst = 1 + rng.next_index(64) as u32;
                for _ in 0..burst.min(n - next) {
                    d.push(next);
                    next += 1;
                }
                for _ in 0..rng.next_index(48) {
                    if let Some(t) = d.pop() {
                        taken[t as usize].fetch_add(1, Ordering::Relaxed);
                        owner_got += 1;
                    }
                }
            }
            stop.store(true, Ordering::Release);
            let stolen: u64 = thieves.into_iter().map(|h| h.join().unwrap()).sum();
            // Owner drains its own leftovers last.
            while let Some(t) = d.pop() {
                taken[t as usize].fetch_add(1, Ordering::Relaxed);
                owner_got += 1;
            }
            assert_eq!(owner_got + stolen, n as u64, "seed {seed}: tasks lost or duplicated");
            for (t, c) in taken.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "seed {seed}: task {t} seen != once");
            }
        }
    }
}
