//! Recycled aggregation-buffer pool.
//!
//! Every flush used to move a `Vec<u8>` out of the outbox and leave a
//! fresh, capacity-less vector behind — one heap allocation (plus growth
//! re-allocations) per flushed packet, on both engines' hot paths. The
//! pool closes that loop: consumers return spent packet buffers after
//! decoding, and [`RankState::flush_one`](crate::ghs::rank::RankState)
//! takes its outbox replacement from the pool, so in steady state buffers
//! round-trip sender → interconnect → receiver → pool → sender with zero
//! per-packet heap allocation (capacity is retained across trips).
//!
//! One pool is shared by all ranks of a run (`Arc`): in the threaded
//! engine the receiving thread returns buffers that any sender may reuse.
//! The `Mutex` is uncontended in practice — it is taken once per
//! aggregated packet (thousands of messages), not per message.

use std::sync::Mutex;

use crate::ghs::ring::lock_clean;

/// Keep at most this many idle buffers (bounds worst-case retained memory
/// to `MAX_POOLED × max_msg_size`; beyond it, buffers just drop).
const MAX_POOLED: usize = 1024;

/// A shared free list of spent aggregation buffers.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<u8>>>,
}

impl BufferPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a cleared buffer; the flag is `true` when it was recycled from
    /// the pool (capacity retained) rather than freshly created.
    ///
    /// A peer thread panicking while holding the pool lock (poison) must
    /// not disable the pool: the free list is just a `Vec` of owned
    /// buffers, structurally valid across any payload panic, so
    /// [`lock_clean`] keeps recycling through it.
    pub fn get(&self) -> (Vec<u8>, bool) {
        match lock_clean(&self.free).pop() {
            Some(buf) => (buf, true),
            None => (Vec::new(), false),
        }
    }

    /// Return a spent buffer to the pool (cleared, capacity kept).
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut f = lock_clean(&self.free);
        if f.len() < MAX_POOLED {
            f.push(buf);
        }
    }

    /// Return a batch of spent buffers under one lock acquisition. The
    /// async engine's workers drain a task's whole mailbox ring (plus any
    /// overflow spill) per quantum and hand the spent packet buffers back
    /// here in one batch; with many workers sharing one pool, taking the
    /// mutex once per ring drain (instead of once per packet) keeps the
    /// pool off the contention path even at 64+ workers.
    pub fn put_all<I: IntoIterator<Item = Vec<u8>>>(&self, bufs: I) {
        let mut f = lock_clean(&self.free);
        for mut buf in bufs {
            if buf.capacity() == 0 {
                continue;
            }
            buf.clear();
            if f.len() < MAX_POOLED {
                f.push(buf);
            }
        }
    }

    /// Idle buffers currently pooled.
    pub fn idle(&self) -> usize {
        lock_clean(&self.free).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_retains_capacity() {
        let pool = BufferPool::new();
        let (mut a, hit) = pool.get();
        assert!(!hit, "empty pool allocates");
        a.extend_from_slice(&[1, 2, 3, 4]);
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let (b, hit) = pool.get();
        assert!(hit, "second get recycles");
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert!(b.capacity() >= cap.min(4));
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn capacityless_buffers_are_not_pooled() {
        let pool = BufferPool::new();
        pool.put(Vec::new());
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn batch_put_recycles_under_one_lock() {
        let pool = BufferPool::new();
        let bufs: Vec<Vec<u8>> = (0..3).map(|i| vec![i as u8; 8]).collect();
        pool.put_all(bufs.into_iter().chain(std::iter::once(Vec::new())));
        assert_eq!(pool.idle(), 3, "capacityless buffers skipped, rest pooled");
        let (b, hit) = pool.get();
        assert!(hit && b.is_empty() && b.capacity() >= 8);
    }

    #[test]
    fn poisoned_pool_keeps_recycling() {
        // Regression: the old `.lock().ok()` paths silently dropped every
        // buffer (and reported idle() == 0) forever after one peer panic.
        use std::sync::Arc;
        let pool = Arc::new(BufferPool::new());
        pool.put(Vec::with_capacity(32));
        let p2 = Arc::clone(&pool);
        let _ = std::thread::spawn(move || {
            let _g = p2.free.lock().unwrap();
            panic!("poison the pool lock");
        })
        .join();
        assert!(pool.free.is_poisoned());
        assert_eq!(pool.idle(), 1, "pooled buffer survives the poison");
        let (b, hit) = pool.get();
        assert!(hit && b.capacity() >= 32, "get still recycles");
        pool.put(b);
        pool.put_all(vec![Vec::with_capacity(8)]);
        assert_eq!(pool.idle(), 2, "put/put_all still pool after poison");
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let pool = Arc::new(BufferPool::new());
        let p2 = Arc::clone(&pool);
        let h = std::thread::spawn(move || {
            let mut b = Vec::with_capacity(64);
            b.push(7u8);
            p2.put(b);
        });
        h.join().unwrap();
        let (b, hit) = pool.get();
        assert!(hit && b.is_empty() && b.capacity() >= 64);
    }
}
