//! Shared utilities: PRNG, bit packing, statistics, property-test harness.

pub mod bitpack;
pub mod minitest;
pub mod prng;
pub mod stats;
