//! Deterministic pseudo-random number generation.
//!
//! The crate builds fully offline, so we do not depend on `rand`. We provide
//! SplitMix64 (for seeding) and xoshiro256** (the workhorse generator used by
//! the graph generators and the property-test harness). Both are
//! well-studied, public-domain algorithms with excellent statistical quality
//! for simulation workloads.

/// SplitMix64: used to expand a single `u64` seed into generator state.
///
/// Reference: Steele, Lea, Flood, "Fast Splittable Pseudorandom Number
/// Generators", OOPSLA 2014.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality 256-bit-state generator.
///
/// Reference: Blackman & Vigna, "Scrambled Linear Pseudorandom Number
/// Generators", ACM TOMS 2021.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors (never all-zero).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper bits of the 64-bit output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in the half-open interval `[0, 1)`.
    ///
    /// Uses the top 53 bits so every representable output is equally likely.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in the *open* interval `(0, 1)` — the paper draws edge
    /// weights from (0, 1), excluding exact zero.
    #[inline]
    pub fn next_weight(&mut self) -> f64 {
        loop {
            let w = self.next_f64();
            if w > 0.0 {
                return w;
            }
        }
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift rejection
    /// method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (cross-checked against the reference C
        // implementation).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_is_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn weight_strictly_positive() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let w = r.next_weight();
            assert!(w > 0.0 && w < 1.0);
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn next_below_one_is_zero() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..100 {
            assert_eq!(r.next_below(1), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }
}
