//! Bit-level packing used by the compact wire formats (paper §3.5).
//!
//! The paper packs "short" messages into 80 bits and "long" messages into
//! 152 bits; neither is byte-structure friendly (a 16-bit packed header with
//! 3-bit type / 5-bit level / 1-bit state fields), so we provide an explicit
//! little-endian bit writer/reader pair with exact-width field access.

/// Append-only bit writer. Bits are emitted LSB-first within each byte.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in `buf` (may not be byte-aligned).
    bits: usize,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Writer that appends to an existing byte buffer (must be byte-aligned).
    pub fn over(buf: Vec<u8>) -> Self {
        let bits = buf.len() * 8;
        Self { buf, bits }
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.bits
    }

    /// Write the low `width` bits of `value` (LSB-first). `width <= 64`.
    pub fn write(&mut self, value: u64, width: usize) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value < (1u64 << width), "value {value} overflows {width} bits");
        let mut remaining = width;
        let mut v = value;
        while remaining > 0 {
            let bit_in_byte = self.bits % 8;
            if bit_in_byte == 0 {
                self.buf.push(0);
            }
            let take = (8 - bit_in_byte).min(remaining);
            let byte = self.buf.last_mut().expect("pushed above");
            *byte |= ((v & ((1u64 << take) - 1)) as u8) << bit_in_byte;
            v >>= take;
            self.bits += take;
            remaining -= take;
        }
    }

    /// Pad with zero bits up to the next byte boundary.
    pub fn align_byte(&mut self) {
        let rem = self.bits % 8;
        if rem != 0 {
            self.write(0, 8 - rem);
        }
    }

    /// Finish and return the underlying bytes (zero-padded to a whole byte).
    pub fn into_bytes(mut self) -> Vec<u8> {
        self.align_byte();
        self.buf
    }
}

/// Bit reader over a byte slice; mirror of [`BitWriter`].
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    /// New reader at bit offset 0.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// New reader starting at a byte offset.
    pub fn at_byte(buf: &'a [u8], byte: usize) -> Self {
        Self { buf, pos: byte * 8 }
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Read `width` bits (LSB-first), advancing the cursor.
    pub fn read(&mut self, width: usize) -> u64 {
        debug_assert!(width <= 64);
        assert!(self.remaining() >= width, "bit underflow: want {width}, have {}", self.remaining());
        let mut out = 0u64;
        let mut got = 0usize;
        while got < width {
            let byte = self.buf[self.pos / 8];
            let bit_in_byte = self.pos % 8;
            let take = (8 - bit_in_byte).min(width - got);
            let chunk = ((byte >> bit_in_byte) as u64) & ((1u64 << take) - 1);
            out |= chunk << got;
            got += take;
            self.pos += take;
        }
        out
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        let rem = self.pos % 8;
        if rem != 0 {
            self.pos += 8 - rem;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn roundtrip_single_fields() {
        for width in 1..=64usize {
            let value = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
            let mut w = BitWriter::new();
            w.write(value, width);
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), (width + 7) / 8);
            let mut r = BitReader::new(&bytes);
            assert_eq!(r.read(width), value, "width {width}");
        }
    }

    #[test]
    fn roundtrip_header_like_layout() {
        // The paper's 16-bit header: 3-bit type, 5-bit level, 1-bit state,
        // 7 bits reserved — then two 32-bit ids.
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        w.write(21, 5);
        w.write(1, 1);
        w.write(0, 7);
        w.write(0xDEAD_BEEF, 32);
        w.write(0x1234_5678, 32);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 10); // exactly 80 bits
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read(5), 21);
        assert_eq!(r.read(1), 1);
        assert_eq!(r.read(7), 0);
        assert_eq!(r.read(32), 0xDEAD_BEEF);
        assert_eq!(r.read(32), 0x1234_5678);
    }

    #[test]
    fn property_random_field_sequences_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(0xB17);
        for _case in 0..500 {
            let nfields = 1 + rng.next_index(12);
            let mut fields = Vec::with_capacity(nfields);
            let mut w = BitWriter::new();
            for _ in 0..nfields {
                let width = 1 + rng.next_index(64);
                let value = if width == 64 {
                    rng.next_u64()
                } else {
                    rng.next_u64() & ((1u64 << width) - 1)
                };
                w.write(value, width);
                fields.push((value, width));
            }
            let total: usize = fields.iter().map(|&(_, w)| w).sum();
            assert_eq!(w.bit_len(), total);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &(value, width) in &fields {
                assert_eq!(r.read(width), value);
            }
        }
    }

    #[test]
    fn append_over_existing_buffer() {
        let mut w = BitWriter::new();
        w.write(0xAB, 8);
        let bytes = w.into_bytes();
        let mut w2 = BitWriter::over(bytes);
        w2.write(0xCD, 8);
        let bytes = w2.into_bytes();
        assert_eq!(bytes, vec![0xAB, 0xCD]);
    }

    #[test]
    fn unaligned_fields_pack_lsb_first_exact_bytes() {
        // Hand-computed layout: 1 + 4 + 3 bits, LSB-first within the byte.
        //   bit 0        = 1            (value 0b1)
        //   bits 1..5    = 0,1,0,1     (value 0b1010, LSB first)
        //   bits 5..8    = 1,1,1       (value 0b111)
        // => byte = 1 | 0b0101<<1 | 0b111<<5 = 0xF5
        let mut w = BitWriter::new();
        w.write(0b1, 1);
        w.write(0b1010, 4);
        w.write(0b111, 3);
        assert_eq!(w.bit_len(), 8);
        assert_eq!(w.into_bytes(), vec![0xF5]);
    }

    #[test]
    fn over_appends_after_unaligned_prefix_was_byte_aligned() {
        // An unaligned writer must be byte-aligned (align_byte / into_bytes)
        // before `over` can continue the buffer; appended unaligned fields
        // then read back across the boundary.
        let mut w = BitWriter::new();
        w.write(0b101, 3);
        assert_eq!(w.bit_len(), 3);
        w.align_byte();
        assert_eq!(w.bit_len(), 8, "align pads to the byte boundary");
        let prefix = w.into_bytes();
        assert_eq!(prefix, vec![0b0000_0101]);

        let mut w2 = BitWriter::over(prefix);
        assert_eq!(w2.bit_len(), 8, "over resumes at the byte boundary");
        w2.write(0b11, 2);
        w2.write(0x15, 5); // 0b10101
        w2.write(0b1, 1);
        assert_eq!(w2.bit_len(), 16);
        let bytes = w2.into_bytes();
        assert_eq!(bytes.len(), 2);

        // Whole-stream read.
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 0b101);
        r.align_byte();
        assert_eq!(r.bit_pos(), 8);
        assert_eq!(r.read(2), 0b11);
        assert_eq!(r.read(5), 0x15);
        assert_eq!(r.read(1), 0b1);
        assert_eq!(r.remaining(), 0);

        // Suffix-only read via a byte offset.
        let mut r = BitReader::at_byte(&bytes, 1);
        assert_eq!(r.read(2), 0b11);
        assert_eq!(r.read(5), 0x15);
    }

    #[test]
    fn property_over_roundtrips_appended_fields() {
        // `over` on a random aligned prefix + random unaligned field tail:
        // the tail reads back exactly from the prefix's byte offset.
        let mut rng = Xoshiro256::seed_from_u64(0x0FE2);
        for _case in 0..300 {
            let prefix_len = rng.next_index(9);
            let prefix: Vec<u8> = (0..prefix_len).map(|_| rng.next_u64() as u8).collect();
            let mut w = BitWriter::over(prefix.clone());
            let nfields = 1 + rng.next_index(8);
            let mut fields = Vec::with_capacity(nfields);
            for _ in 0..nfields {
                let width = 1 + rng.next_index(64);
                let value = if width == 64 {
                    rng.next_u64()
                } else {
                    rng.next_u64() & ((1u64 << width) - 1)
                };
                w.write(value, width);
                fields.push((value, width));
            }
            let total_bits: usize = fields.iter().map(|&(_, w)| w).sum();
            assert_eq!(w.bit_len(), prefix_len * 8 + total_bits);
            let bytes = w.into_bytes();
            assert_eq!(&bytes[..prefix_len], &prefix[..], "prefix untouched");
            let mut r = BitReader::at_byte(&bytes, prefix_len);
            for &(value, width) in &fields {
                assert_eq!(r.read(width), value);
            }
        }
    }

    #[test]
    fn reader_at_byte_offset() {
        let bytes = vec![0xFF, 0x0F];
        let mut r = BitReader::at_byte(&bytes, 1);
        assert_eq!(r.read(8), 0x0F);
    }

    #[test]
    #[should_panic(expected = "bit underflow")]
    fn underflow_panics() {
        let bytes = vec![0u8];
        let mut r = BitReader::new(&bytes);
        r.read(9);
    }
}
