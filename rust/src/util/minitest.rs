//! Minimal property-testing harness (the vendored crate set has no
//! `proptest`/`quickcheck`, so we provide a small seeded-case runner).
//!
//! Usage (`no_run`: doctest binaries do not inherit the workspace rpath
//! flags needed to locate the PJRT shared library this crate links):
//! ```no_run
//! use ghs_mst::util::minitest::{props, Gen};
//! props("addition commutes", 100, |g| {
//!     let a = g.u64_below(1000);
//!     let b = g.u64_below(1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a deterministic PRNG derived from (suite-seed, case index);
//! a failure panics with the case index and seed so the exact case can be
//! replayed with [`replay`].

use crate::util::prng::Xoshiro256;

/// Per-case random value source handed to property closures.
pub struct Gen {
    rng: Xoshiro256,
    /// Case index within the suite (usable to scale case sizes).
    pub case: usize,
}

impl Gen {
    /// Uniform u64 in [0, bound).
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        self.rng.next_below(bound)
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_index(hi - lo + 1)
    }

    /// Uniform f64 in [0,1).
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_bool(p)
    }

    /// Raw u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Access the underlying generator (for passing to graph generators).
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_index(xs.len())]
    }
}

/// Default suite seed; override with env `MINITEST_SEED` for exploration.
fn suite_seed() -> u64 {
    std::env::var("MINITEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x6853_4D53_5400_0001) // "GHSMST"
}

/// Run `cases` property cases. Panics (with replay info) on first failure.
pub fn props(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let seed = suite_seed();
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Xoshiro256::seed_from_u64(case_seed), case };
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (case_seed={case_seed:#x}): {msg}\n\
                 replay with ghs_mst::util::minitest::replay({case_seed:#x}, ..)"
            );
        }
    }
}

/// Replay a single failing case by its reported `case_seed`.
pub fn replay(case_seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen { rng: Xoshiro256::seed_from_u64(case_seed), case: 0 };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_run_all_cases() {
        let mut count = 0;
        props("counting", 37, |_g| {
            count += 1;
        });
        assert_eq!(count, 37);
    }

    #[test]
    fn props_are_deterministic_across_runs() {
        let mut first = Vec::new();
        props("collect", 10, |g| first.push(g.u64()));
        let mut second = Vec::new();
        props("collect", 10, |g| second.push(g.u64()));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed at case 0")]
    fn failure_reports_case() {
        props("always fails", 5, |_g| panic!("boom"));
    }

    #[test]
    fn gen_ranges() {
        props("ranges", 200, |g| {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
            let y = g.u64_below(5);
            assert!(y < 5);
            let f = g.f64();
            assert!((0.0..1.0).contains(&f));
        });
    }

    #[test]
    fn replay_reproduces() {
        let mut v1 = 0;
        replay(0xABCD, |g| v1 = g.u64());
        let mut v2 = 0;
        replay(0xABCD, |g| v2 = g.u64());
        assert_eq!(v1, v2);
    }
}
