//! Small statistics helpers shared by the benchmark harness and the
//! simulator: summary statistics, percentiles, fixed-width table rendering
//! and human time formatting.

use std::fmt::Write as _;
use std::time::Duration;

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute summary statistics of `xs`. Returns `None` for an empty slice.
    pub fn of(xs: &[f64]) -> Option<Self> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Some(Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        })
    }
}

/// Percentile (0..=100) of an already-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Format a duration in adaptive units (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    fmt_seconds(d.as_secs_f64())
}

/// Format seconds in adaptive units.
pub fn fmt_seconds(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", fmt_seconds(-s));
    }
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a byte count in adaptive units.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else {
        format!("{b:.0} B")
    }
}

/// Render a markdown table: header row + aligned body rows.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let emit_row = |out: &mut String, cells: &[String]| {
        out.push('|');
        for (i, &w) in widths.iter().enumerate() {
            let cell = cells.get(i).map(String::as_str).unwrap_or("");
            let _ = write!(out, " {cell:<w$} |");
        }
        out.push('\n');
    };
    emit_row(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push('|');
    for w in &widths {
        let _ = write!(out, "{}|", "-".repeat(w + 2));
    }
    out.push('\n');
    for row in rows {
        emit_row(&mut out, row);
    }
    out
}

/// Simple fixed-bucket histogram for message-size style data.
#[derive(Debug, Clone)]
pub struct Histogram {
    bucket_width: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Histogram with `nbuckets` buckets of `bucket_width` each; values above
    /// the range land in the last bucket.
    pub fn new(bucket_width: f64, nbuckets: usize) -> Self {
        assert!(bucket_width > 0.0 && nbuckets > 0);
        Self { bucket_width, counts: vec![0; nbuckets], total: 0, sum: 0.0 }
    }

    /// Record a value.
    pub fn record(&mut self, v: f64) {
        let idx = ((v / self.bucket_width) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of recorded values (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Bucket counts.
    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn duration_formatting_units() {
        assert_eq!(fmt_seconds(2.5), "2.500 s");
        assert_eq!(fmt_seconds(0.0025), "2.500 ms");
        assert_eq!(fmt_seconds(2.5e-6), "2.500 µs");
        assert_eq!(fmt_seconds(2.5e-9), "2.5 ns");
    }

    #[test]
    fn bytes_formatting_units() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }

    #[test]
    fn markdown_table_renders() {
        let t = markdown_table(&["a", "bb"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | bb |"));
        assert!(t.lines().count() == 3);
    }

    #[test]
    fn histogram_buckets_and_mean() {
        let mut h = Histogram::new(10.0, 4);
        for v in [1.0, 11.0, 21.0, 100.0] {
            h.record(v);
        }
        assert_eq!(h.buckets(), &[1, 1, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 33.25).abs() < 1e-12);
    }
}
