//! R-MAT recursive-matrix graph generator (Chakrabarti, Zhan, Faloutsos,
//! SDM 2004 — the paper's ref [20]).
//!
//! Uses the Graph500 parameterization a=0.57, b=0.19, c=0.19, d=0.05,
//! which produces the skewed power-law degree distributions the paper
//! describes as "real-world large-scale graphs from social networks and
//! Internet". Vertex ids are scrambled by a random permutation so locality
//! does not leak into the block partitioning.

use crate::graph::{EdgeList, VertexId};
use crate::util::prng::Xoshiro256;

/// Graph500 R-MAT probabilities.
pub const A: f64 = 0.57;
pub const B: f64 = 0.19;
pub const C: f64 = 0.19;

/// Generate an R-MAT graph with `2^scale` vertices and
/// `edge_factor * 2^scale` undirected edges.
pub fn rmat(scale: u32, edge_factor: usize, rng: &mut Xoshiro256) -> EdgeList {
    assert!(scale <= 31, "vertex ids are 32-bit");
    let n: u64 = 1 << scale;
    let m = edge_factor * n as usize;
    let mut g = EdgeList::with_vertices(n as u32);
    g.edges.reserve(m);

    // Random vertex relabelling (Graph500-style scramble).
    let mut perm: Vec<VertexId> = (0..n as u32).collect();
    rng.shuffle(&mut perm);

    for _ in 0..m {
        let (u, v) = rmat_edge(scale, rng);
        g.push(perm[u as usize], perm[v as usize], rng.next_weight());
    }
    g
}

/// Sample one R-MAT edge by recursive quadrant descent with per-level
/// probability noise (+-10%), as in the reference implementation.
fn rmat_edge(scale: u32, rng: &mut Xoshiro256) -> (u64, u64) {
    let mut u = 0u64;
    let mut v = 0u64;
    let (mut a, mut b, mut c) = (A, B, C);
    for level in 0..scale {
        let bit = 1u64 << (scale - 1 - level);
        let r = rng.next_f64();
        if r < a {
            // top-left: nothing set
        } else if r < a + b {
            v |= bit;
        } else if r < a + b + c {
            u |= bit;
        } else {
            u |= bit;
            v |= bit;
        }
        // Jitter the quadrant probabilities each level (keeps the matrix
        // from being exactly self-similar; standard R-MAT practice).
        let noise = |p: f64, rng: &mut Xoshiro256| p * (0.9 + 0.2 * rng.next_f64());
        a = noise(a, rng);
        b = noise(b, rng);
        c = noise(c, rng);
        let d = noise(1.0 - (A + B + C), rng);
        let total = a + b + c + d;
        a /= total;
        b /= total;
        c /= total;
    }
    (u, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_parameters() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let g = rmat(10, 16, &mut rng);
        assert_eq!(g.n_vertices, 1024);
        assert_eq!(g.n_edges(), 16 * 1024);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        // Power-law-ish: the max degree should far exceed the average.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g = rmat(12, 16, &mut rng);
        let mut deg = vec![0u32; g.n_vertices as usize];
        for e in &g.edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let avg = 2.0 * g.n_edges() as f64 / g.n_vertices as f64;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(max > 5.0 * avg, "max {max} avg {avg}");
        // And some vertices should be isolated or near-isolated (heavy skew).
        let low = deg.iter().filter(|&&d| d <= 2).count();
        assert!(low > 0, "expected low-degree tail");
    }

    #[test]
    fn weights_in_open_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let g = rmat(8, 8, &mut rng);
        assert!(g.edges.iter().all(|e| e.w > 0.0 && e.w < 1.0));
    }
}
