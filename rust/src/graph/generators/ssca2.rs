//! SSCA2-style generator (Bader & Madduri, HiPC 2005 — the paper's ref
//! [21]): "set of randomly connected cliques".
//!
//! Vertices are partitioned into cliques of random size up to `max_clique`;
//! all intra-clique edges are present, and cliques are additionally wired
//! together with random inter-clique edges with probability decreasing in
//! clique distance — following the SSCA2 kernel-1 structure. The edge
//! factor steers the number of inter-clique connections so the average
//! degree lands near the paper's 32.

use crate::graph::EdgeList;
use crate::util::prng::Xoshiro256;

/// Default maximum clique size (SSCA2 `MaxCliqueSize` is typically ~2^3..2^5
/// for these scales; cliques of ~8 give intra-clique degree ~7 of the
/// average-32 target, with inter-clique edges supplying the rest).
pub const DEFAULT_MAX_CLIQUE: u32 = 8;

/// Generate an SSCA2-style graph with `2^scale` vertices.
pub fn ssca2(scale: u32, edge_factor: usize, rng: &mut Xoshiro256) -> EdgeList {
    ssca2_with_cliques(scale, edge_factor, DEFAULT_MAX_CLIQUE, rng)
}

/// Generate with explicit max clique size.
pub fn ssca2_with_cliques(
    scale: u32,
    edge_factor: usize,
    max_clique: u32,
    rng: &mut Xoshiro256,
) -> EdgeList {
    assert!(scale <= 31, "vertex ids are 32-bit");
    assert!(max_clique >= 1);
    let n: u64 = 1 << scale;
    let mut g = EdgeList::with_vertices(n as u32);

    // Partition [0, n) into contiguous cliques of random size 1..=max_clique.
    let mut clique_start: Vec<u32> = Vec::new();
    let mut at: u64 = 0;
    while at < n {
        clique_start.push(at as u32);
        let size = 1 + rng.next_below(max_clique as u64);
        at += size;
    }
    clique_start.push(n as u32); // sentinel
    let n_cliques = clique_start.len() - 1;

    // Intra-clique: all pairs.
    let mut intra = 0usize;
    for c in 0..n_cliques {
        let (s, e) = (clique_start[c], clique_start[c + 1]);
        for u in s..e {
            for v in (u + 1)..e {
                g.push(u, v, rng.next_weight());
                intra += 1;
            }
        }
    }

    // Inter-clique: random edges between members of distinct cliques until
    // the total edge budget (edge_factor * n) is met. Prefer nearby cliques
    // (geometric-ish distance decay), as in SSCA2.
    let budget = (edge_factor * n as usize).saturating_sub(intra);
    for _ in 0..budget {
        let c1 = rng.next_index(n_cliques);
        // Distance decay: step 2^k cliques away, k geometric.
        let mut dist: usize = 1;
        while dist < n_cliques && rng.next_bool(0.5) {
            dist *= 2;
        }
        let c2 = (c1 + dist) % n_cliques;
        if c1 == c2 {
            continue;
        }
        let pick = |c: usize, rng: &mut Xoshiro256| {
            let (s, e) = (clique_start[c], clique_start[c + 1]);
            s + rng.next_below((e - s) as u64) as u32
        };
        let u = pick(c1, rng);
        let v = pick(c2, rng);
        g.push(u, v, rng.next_weight());
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_cliques_and_connections() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let g = ssca2(10, 16, &mut rng);
        assert_eq!(g.n_vertices, 1024);
        // Budgeted to land near the edge-factor target.
        let target = 16 * 1024;
        assert!(g.n_edges() >= target * 9 / 10, "{} edges", g.n_edges());
    }

    #[test]
    fn clique_members_are_fully_connected() {
        // With max_clique=4 and zero inter-clique budget (edge_factor=0 ->
        // budget saturates to 0), the graph is exactly a disjoint union of
        // cliques: every component's edge count is k*(k-1)/2.
        let mut rng = Xoshiro256::seed_from_u64(9);
        let g = ssca2_with_cliques(6, 0, 4, &mut rng);
        // Count degrees: within a clique of size k every member has k-1.
        let mut deg = vec![0u32; g.n_vertices as usize];
        for e in &g.edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        // All degrees < max_clique.
        assert!(deg.iter().all(|&d| d < 4));
    }

    #[test]
    fn single_vertex_cliques_allowed() {
        let mut rng = Xoshiro256::seed_from_u64(10);
        let g = ssca2_with_cliques(4, 0, 1, &mut rng);
        assert_eq!(g.n_edges(), 0, "all cliques size 1 -> no intra edges");
    }
}
