//! Uniformly Random (Erdős–Rényi G(n, m)) generator — the paper's ref [22]:
//! "neighbours of each vertex are chosen randomly".

use crate::graph::EdgeList;
use crate::util::prng::Xoshiro256;

/// Generate a uniformly random graph with `2^scale` vertices and
/// `edge_factor * 2^scale` undirected edges; endpoints drawn i.i.d.
/// uniformly (self-loops allowed here, removed by preprocessing — matching
/// the paper, which preprocesses loops/multi-edges away, §3.1).
pub fn uniform_random(scale: u32, edge_factor: usize, rng: &mut Xoshiro256) -> EdgeList {
    assert!(scale <= 31, "vertex ids are 32-bit");
    let n: u64 = 1 << scale;
    let m = edge_factor * n as usize;
    let mut g = EdgeList::with_vertices(n as u32);
    g.edges.reserve(m);
    for _ in 0..m {
        let u = rng.next_below(n) as u32;
        let v = rng.next_below(n) as u32;
        g.push(u, v, rng.next_weight());
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let g = uniform_random(10, 16, &mut rng);
        assert_eq!(g.n_vertices, 1024);
        assert_eq!(g.n_edges(), 16 * 1024);
    }

    #[test]
    fn degrees_are_concentrated() {
        // Unlike R-MAT, the uniform model has a binomial degree
        // distribution: max degree stays within a small factor of average.
        let mut rng = Xoshiro256::seed_from_u64(6);
        let g = uniform_random(12, 16, &mut rng);
        let mut deg = vec![0u32; g.n_vertices as usize];
        for e in &g.edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let avg = 2.0 * g.n_edges() as f64 / g.n_vertices as f64;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(max < 3.0 * avg, "max {max} avg {avg}");
    }
}
