//! Small structured graphs with known MSTs, used heavily by the test suite:
//! paths, cycles, stars, grids, complete graphs, and disjoint unions for
//! the forest generalization.

use crate::graph::{EdgeList, VertexId};
use crate::util::prng::Xoshiro256;

/// Path 0-1-2-…-(n-1) with the given weights (len n-1). The MST is the
/// whole path.
pub fn path(n: u32, rng: &mut Xoshiro256) -> EdgeList {
    let mut g = EdgeList::with_vertices(n);
    for i in 0..n.saturating_sub(1) {
        g.push(i, i + 1, rng.next_weight());
    }
    g
}

/// Cycle of n vertices. The MST drops exactly the heaviest edge.
pub fn cycle(n: u32, rng: &mut Xoshiro256) -> EdgeList {
    assert!(n >= 3);
    let mut g = path(n, rng);
    g.push(n - 1, 0, rng.next_weight());
    g
}

/// Star with center 0 and n-1 leaves. The MST is the whole star.
pub fn star(n: u32, rng: &mut Xoshiro256) -> EdgeList {
    let mut g = EdgeList::with_vertices(n);
    for i in 1..n {
        g.push(0, i, rng.next_weight());
    }
    g
}

/// rows × cols grid graph.
pub fn grid(rows: u32, cols: u32, rng: &mut Xoshiro256) -> EdgeList {
    let n = rows * cols;
    let mut g = EdgeList::with_vertices(n);
    let id = |r: u32, c: u32| r * cols + c;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                g.push(id(r, c), id(r, c + 1), rng.next_weight());
            }
            if r + 1 < rows {
                g.push(id(r, c), id(r + 1, c), rng.next_weight());
            }
        }
    }
    g
}

/// Complete graph K_n.
pub fn complete(n: u32, rng: &mut Xoshiro256) -> EdgeList {
    let mut g = EdgeList::with_vertices(n);
    for u in 0..n {
        for v in (u + 1)..n {
            g.push(u, v, rng.next_weight());
        }
    }
    g
}

/// Disjoint union: shift `b`'s vertex ids above `a`'s. Used to build
/// disconnected inputs for the minimum-spanning-forest tests.
pub fn disjoint_union(a: &EdgeList, b: &EdgeList) -> EdgeList {
    let mut g = EdgeList::with_vertices(a.n_vertices + b.n_vertices);
    g.edges.extend_from_slice(&a.edges);
    for e in &b.edges {
        g.push(e.u + a.n_vertices, e.v + a.n_vertices, e.w);
    }
    g
}

/// Add `extra` isolated vertices (no incident edges).
pub fn with_isolated(a: &EdgeList, extra: u32) -> EdgeList {
    let mut g = a.clone();
    g.n_vertices += extra;
    g
}

/// A connected random graph: random spanning tree + `extra_edges` random
/// chords. Always connected, arbitrary topology — the workhorse for
/// property tests.
pub fn connected_random(n: u32, extra_edges: usize, rng: &mut Xoshiro256) -> EdgeList {
    assert!(n >= 1);
    let mut g = EdgeList::with_vertices(n);
    // Random spanning tree: attach each vertex i>0 to a uniformly random
    // earlier vertex (random recursive tree).
    let mut order: Vec<VertexId> = (0..n).collect();
    rng.shuffle(&mut order);
    for i in 1..n as usize {
        let parent = order[rng.next_index(i)];
        g.push(order[i], parent, rng.next_weight());
    }
    for _ in 0..extra_edges {
        let u = rng.next_below(n as u64) as u32;
        let v = rng.next_below(n as u64) as u32;
        if u != v {
            g.push(u, v, rng.next_weight());
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::connectivity::components;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(123)
    }

    #[test]
    fn shapes() {
        let mut r = rng();
        assert_eq!(path(5, &mut r).n_edges(), 4);
        assert_eq!(cycle(5, &mut r).n_edges(), 5);
        assert_eq!(star(5, &mut r).n_edges(), 4);
        assert_eq!(grid(3, 4, &mut r).n_edges(), 3 * 3 + 2 * 4);
        assert_eq!(complete(5, &mut r).n_edges(), 10);
    }

    #[test]
    fn connected_random_is_connected() {
        let mut r = rng();
        for n in [1u32, 2, 3, 10, 50] {
            let g = connected_random(n, 5, &mut r);
            assert_eq!(components(&g).count, 1, "n={n}");
        }
    }

    #[test]
    fn disjoint_union_components_add() {
        let mut r = rng();
        let a = connected_random(10, 3, &mut r);
        let b = connected_random(7, 2, &mut r);
        let u = disjoint_union(&a, &b);
        assert_eq!(u.n_vertices, 17);
        assert_eq!(components(&u).count, 2);
    }

    #[test]
    fn isolated_vertices_counted() {
        let mut r = rng();
        let g = with_isolated(&connected_random(5, 0, &mut r), 3);
        assert_eq!(components(&g).count, 4);
    }
}
