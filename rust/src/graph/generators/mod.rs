//! Synthetic graph generators used in the paper's evaluation (§4):
//! R-MAT, SSCA2 and Uniformly Random, plus small structured graphs for
//! tests. All generators follow the paper's conventions: `2^scale`
//! vertices, average vertex degree 32 (edge factor 16) by default, edge
//! weights uniform in the open interval (0, 1).

pub mod random;
pub mod rmat;
pub mod ssca2;
pub mod structured;

use crate::graph::EdgeList;
use crate::util::prng::Xoshiro256;

/// Edge factor: edges = factor * vertices. Average degree = 2 * factor.
/// The paper uses average degree 32, i.e. factor 16.
pub const DEFAULT_EDGE_FACTOR: usize = 16;

/// Which synthetic family to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphFamily {
    /// R-MAT power-law graphs (social-network-like), paper ref [20].
    Rmat,
    /// SSCA2: randomly connected cliques, paper ref [21].
    Ssca2,
    /// Erdős–Rényi uniformly random graphs, paper ref [22].
    Random,
}

impl GraphFamily {
    /// Parse a family name (`rmat` / `ssca2` / `random`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "rmat" | "r-mat" => Some(Self::Rmat),
            "ssca2" | "ssca" => Some(Self::Ssca2),
            "random" | "uniform" | "er" => Some(Self::Random),
            _ => None,
        }
    }

    /// Display name matching the paper's naming (e.g. `RMAT-24`).
    pub fn label(&self) -> &'static str {
        match self {
            Self::Rmat => "RMAT",
            Self::Ssca2 => "SSCA2",
            Self::Random => "Random",
        }
    }
}

/// Generate a graph of the given family at `scale` (2^scale vertices) with
/// the paper's default edge factor, deterministically from `seed`.
pub fn generate(family: GraphFamily, scale: u32, seed: u64) -> EdgeList {
    generate_with_factor(family, scale, DEFAULT_EDGE_FACTOR, seed)
}

/// Generate with an explicit edge factor.
pub fn generate_with_factor(
    family: GraphFamily,
    scale: u32,
    edge_factor: usize,
    seed: u64,
) -> EdgeList {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    match family {
        GraphFamily::Rmat => rmat::rmat(scale, edge_factor, &mut rng),
        GraphFamily::Ssca2 => ssca2::ssca2(scale, edge_factor, &mut rng),
        GraphFamily::Random => random::uniform_random(scale, edge_factor, &mut rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_parsing() {
        assert_eq!(GraphFamily::parse("rmat"), Some(GraphFamily::Rmat));
        assert_eq!(GraphFamily::parse("SSCA2"), Some(GraphFamily::Ssca2));
        assert_eq!(GraphFamily::parse("Random"), Some(GraphFamily::Random));
        assert_eq!(GraphFamily::parse("nope"), None);
    }

    #[test]
    fn all_families_generate_expected_sizes() {
        for family in [GraphFamily::Rmat, GraphFamily::Ssca2, GraphFamily::Random] {
            let g = generate(family, 8, 42);
            assert_eq!(g.n_vertices, 256, "{family:?}");
            // Edge factor 16: SSCA2 is clique-based so only approximately.
            let target = 256 * DEFAULT_EDGE_FACTOR;
            assert!(
                g.n_edges() > target / 2 && g.n_edges() < target * 2,
                "{family:?}: {} edges vs target {target}",
                g.n_edges()
            );
            for e in &g.edges {
                assert!(e.u < 256 && e.v < 256);
                assert!(e.w > 0.0 && e.w < 1.0);
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for family in [GraphFamily::Rmat, GraphFamily::Ssca2, GraphFamily::Random] {
            let a = generate(family, 6, 7);
            let b = generate(family, 6, 7);
            assert_eq!(a.n_edges(), b.n_edges());
            for (x, y) in a.edges.iter().zip(&b.edges) {
                assert_eq!(x.u, y.u);
                assert_eq!(x.v, y.v);
                assert_eq!(x.w, y.w);
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(GraphFamily::Rmat, 6, 1);
        let b = generate(GraphFamily::Rmat, 6, 2);
        let same = a
            .edges
            .iter()
            .zip(&b.edges)
            .filter(|(x, y)| x.u == y.u && x.v == y.v)
            .count();
        assert!(same < a.n_edges() / 2);
    }
}
