//! Connected-component labelling (BFS) — used to verify the forest
//! generalization: a minimum spanning forest must have exactly
//! `n_vertices - n_components` edges.

use std::collections::VecDeque;

use crate::graph::csr::Csr;
use crate::graph::EdgeList;

/// Component labelling result.
#[derive(Debug, Clone)]
pub struct Components {
    /// Component id per vertex (ids are 0..count, assigned in BFS order).
    pub label: Vec<u32>,
    /// Number of connected components.
    pub count: u32,
}

impl Components {
    /// Are `u` and `v` in the same component?
    pub fn same(&self, u: u32, v: u32) -> bool {
        self.label[u as usize] == self.label[v as usize]
    }

    /// Sizes of each component.
    pub fn sizes(&self) -> Vec<u32> {
        let mut sizes = vec![0u32; self.count as usize];
        for &l in &self.label {
            sizes[l as usize] += 1;
        }
        sizes
    }
}

/// Label connected components of an undirected graph.
pub fn components(g: &EdgeList) -> Components {
    let csr = Csr::full(g);
    let n = g.n_vertices as usize;
    let mut label = vec![u32::MAX; n];
    let mut count = 0u32;
    let mut queue = VecDeque::new();
    for start in 0..n {
        if label[start] != u32::MAX {
            continue;
        }
        label[start] = count;
        queue.push_back(start as u32);
        while let Some(v) = queue.pop_front() {
            for (_, nbr, _) in csr.neighbours(v) {
                if label[nbr as usize] == u32::MAX {
                    label[nbr as usize] = count;
                    queue.push_back(nbr);
                }
            }
        }
        count += 1;
    }
    Components { label, count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    #[test]
    fn empty_graph_all_isolated() {
        let g = EdgeList::with_vertices(5);
        let c = components(&g);
        assert_eq!(c.count, 5);
        assert_eq!(c.sizes(), vec![1; 5]);
    }

    #[test]
    fn two_triangles() {
        let mut g = EdgeList::with_vertices(6);
        for (u, v) in [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)] {
            g.push(u, v, 0.5);
        }
        let c = components(&g);
        assert_eq!(c.count, 2);
        assert!(c.same(0, 2));
        assert!(c.same(3, 5));
        assert!(!c.same(0, 3));
        assert_eq!(c.sizes(), vec![3, 3]);
    }

    #[test]
    fn single_component() {
        let mut g = EdgeList::with_vertices(4);
        for (u, v) in [(0, 1), (1, 2), (2, 3)] {
            g.push(u, v, 0.1);
        }
        assert_eq!(components(&g).count, 1);
    }
}
