//! CRS (Compressed Row Storage) representation of the *local* part of the
//! graph held by one rank (paper §3: "The local part of the graph in each
//! process is stored in the CRS format").
//!
//! Rows are the rank-local vertices; each row stores the neighbours of one
//! vertex together with edge weights. The same physical structure is also
//! used (with all vertices local) by the sequential baselines.
//!
//! The global-id ↔ row mapping is pluggable: contiguous blocks (the
//! paper's layout, a single offset) or an arbitrary vertex set from a
//! mapped [`Partition`] (hub-scatter / explicit owner maps), whose
//! owner/local tables are shared behind an `Arc`.

use std::sync::Arc;

use crate::graph::partition::{MappedData, Partition};
use crate::graph::{EdgeList, VertexId, WeightedEdge};

/// How rows map to global vertex ids.
#[derive(Debug, Clone)]
enum RowIndex {
    /// Rows are the contiguous block `[first, first + rows)`.
    Contiguous { first: VertexId },
    /// Rows are `data.rank_vertices[rank]` (ascending global ids); the
    /// tables are shared with the run's [`Partition`].
    Mapped { rank: u32, data: Arc<MappedData> },
}

/// CRS adjacency over one rank's local vertex set.
#[derive(Debug, Clone)]
pub struct Csr {
    /// Row → global-id mapping.
    index: RowIndex,
    /// Row offsets, length `rows + 1`.
    offsets: Vec<usize>,
    /// Column indices: the global id of the neighbour on the far end.
    cols: Vec<VertexId>,
    /// Edge weights, parallel to `cols`.
    weights: Vec<f64>,
}

impl Csr {
    /// Build the CRS rows for vertices `[first, first + rows)` from an
    /// undirected edge list. Every edge `(u, v)` contributes an entry to
    /// row `u` *and* row `v` (when each falls within the block).
    pub fn from_edges(edges: &EdgeList, first: VertexId, rows: u32) -> Self {
        let in_block = |x: VertexId| x >= first && x < first + rows;
        let mut degree = vec![0usize; rows as usize];
        for e in &edges.edges {
            if in_block(e.u) {
                degree[(e.u - first) as usize] += 1;
            }
            if in_block(e.v) {
                degree[(e.v - first) as usize] += 1;
            }
        }
        let (offsets, mut cols, mut weights) = Self::alloc(&degree);
        let mut cursor = offsets[..rows as usize].to_vec();
        let mut place = |row: VertexId, other: VertexId, w: f64, cursor: &mut [usize]| {
            let r = (row - first) as usize;
            let at = cursor[r];
            cols[at] = other;
            weights[at] = w;
            cursor[r] += 1;
        };
        for e in &edges.edges {
            if in_block(e.u) {
                place(e.u, e.v, e.w, &mut cursor);
            }
            if in_block(e.v) {
                place(e.v, e.u, e.w, &mut cursor);
            }
        }
        Self { index: RowIndex::Contiguous { first }, offsets, cols, weights }
    }

    /// Build `rank`'s CRS block under an arbitrary [`Partition`].
    /// Contiguous partitions use the block layout (identical structure to
    /// [`Self::from_edges`]); mapped ones index rows through the
    /// partition's shared owner/local tables.
    pub fn from_partition(edges: &EdgeList, part: &Partition, rank: u32) -> Self {
        let Some(data) = part.mapped_data() else {
            return Self::from_edges(edges, part.first_vertex(rank), part.n_local(rank));
        };
        let data = Arc::clone(data);
        let rows = data.rank_vertices[rank as usize].len();
        let owned = |x: VertexId| data.owner[x as usize] == rank;
        let mut degree = vec![0usize; rows];
        for e in &edges.edges {
            if owned(e.u) {
                degree[data.local[e.u as usize] as usize] += 1;
            }
            if owned(e.v) {
                degree[data.local[e.v as usize] as usize] += 1;
            }
        }
        let (offsets, mut cols, mut weights) = Self::alloc(&degree);
        let mut cursor = offsets[..rows].to_vec();
        {
            let mut place = |row: usize, other: VertexId, w: f64| {
                let at = cursor[row];
                cols[at] = other;
                weights[at] = w;
                cursor[row] += 1;
            };
            for e in &edges.edges {
                if owned(e.u) {
                    place(data.local[e.u as usize] as usize, e.v, e.w);
                }
                if owned(e.v) {
                    place(data.local[e.v as usize] as usize, e.u, e.w);
                }
            }
        }
        Self { index: RowIndex::Mapped { rank, data }, offsets, cols, weights }
    }

    /// Offsets from per-row degrees plus zeroed column/weight arrays.
    fn alloc(degree: &[usize]) -> (Vec<usize>, Vec<VertexId>, Vec<f64>) {
        let mut offsets = Vec::with_capacity(degree.len() + 1);
        offsets.push(0usize);
        for d in degree {
            offsets.push(offsets.last().unwrap() + d);
        }
        let nnz = *offsets.last().unwrap();
        (offsets, vec![0 as VertexId; nnz], vec![0.0f64; nnz])
    }

    /// Whole-graph CRS (all vertices in one block).
    pub fn full(edges: &EdgeList) -> Self {
        Self::from_edges(edges, 0, edges.n_vertices)
    }

    /// Lowest global vertex id stored in this structure (for contiguous
    /// blocks, the block start). Only meaningful when `rows() > 0`.
    pub fn first_vertex(&self) -> VertexId {
        match &self.index {
            RowIndex::Contiguous { first } => *first,
            RowIndex::Mapped { rank, data } => {
                data.rank_vertices[*rank as usize].first().copied().unwrap_or(0)
            }
        }
    }

    /// Number of rows (local vertices).
    pub fn rows(&self) -> u32 {
        (self.offsets.len() - 1) as u32
    }

    /// Does this block own global vertex `v`?
    pub fn owns(&self, v: VertexId) -> bool {
        match &self.index {
            RowIndex::Contiguous { first } => v >= *first && v - *first < self.rows(),
            RowIndex::Mapped { rank, data } => {
                (v as usize) < data.owner.len() && data.owner[v as usize] == *rank
            }
        }
    }

    /// Total local (directed) adjacency entries.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Local row index of a global vertex id.
    #[inline]
    pub fn row_of(&self, v: VertexId) -> usize {
        debug_assert!(self.owns(v));
        match &self.index {
            RowIndex::Contiguous { first } => (v - *first) as usize,
            RowIndex::Mapped { data, .. } => data.local[v as usize] as usize,
        }
    }

    /// Global vertex id of row `row` (inverse of [`Self::row_of`]).
    #[inline]
    pub fn vertex_of(&self, row: u32) -> VertexId {
        debug_assert!(row < self.rows());
        match &self.index {
            RowIndex::Contiguous { first } => *first + row,
            RowIndex::Mapped { rank, data } => data.rank_vertices[*rank as usize][row as usize],
        }
    }

    /// Half-open range of adjacency indices for local row `row`.
    #[inline]
    pub fn row_range_at(&self, row: usize) -> std::ops::Range<usize> {
        self.offsets[row]..self.offsets[row + 1]
    }

    /// Half-open range of adjacency indices for global vertex `v`.
    #[inline]
    pub fn row_range(&self, v: VertexId) -> std::ops::Range<usize> {
        self.row_range_at(self.row_of(v))
    }

    /// Degree of global vertex `v`.
    pub fn degree(&self, v: VertexId) -> usize {
        self.row_range(v).len()
    }

    /// Neighbour id at adjacency index `i`.
    #[inline]
    pub fn col(&self, i: usize) -> VertexId {
        self.cols[i]
    }

    /// Weight at adjacency index `i`.
    #[inline]
    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Iterate `(adjacency index, neighbour, weight)` over a vertex's row.
    pub fn neighbours(&self, v: VertexId) -> impl Iterator<Item = (usize, VertexId, f64)> + '_ {
        self.row_range(v).map(move |i| (i, self.cols[i], self.weights[i]))
    }

    /// Sort each row by neighbour id (enables binary search lookup,
    /// paper §3.3 first optimization).
    pub fn sort_rows_by_neighbour(&mut self) {
        for r in 0..self.rows() as usize {
            let range = self.offsets[r]..self.offsets[r + 1];
            let mut pairs: Vec<(VertexId, f64)> = range
                .clone()
                .map(|i| (self.cols[i], self.weights[i]))
                .collect();
            pairs.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            for (k, i) in range.enumerate() {
                self.cols[i] = pairs[k].0;
                self.weights[i] = pairs[k].1;
            }
        }
    }

    /// Reconstruct the `WeightedEdge` at adjacency index `i` of row `v`.
    pub fn edge_at(&self, v: VertexId, i: usize) -> WeightedEdge {
        WeightedEdge::new(v, self.cols[i], self.weights[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::props;
    use crate::util::prng::Xoshiro256;

    fn triangle() -> EdgeList {
        let mut g = EdgeList::with_vertices(3);
        g.push(0, 1, 0.1);
        g.push(1, 2, 0.2);
        g.push(2, 0, 0.3);
        g
    }

    #[test]
    fn full_csr_degrees() {
        let csr = Csr::full(&triangle());
        assert_eq!(csr.rows(), 3);
        assert_eq!(csr.nnz(), 6);
        for v in 0..3 {
            assert_eq!(csr.degree(v), 2, "vertex {v}");
        }
    }

    #[test]
    fn block_csr_only_stores_local_rows() {
        let csr = Csr::from_edges(&triangle(), 1, 2); // vertices 1 and 2
        assert_eq!(csr.rows(), 2);
        assert!(!csr.owns(0));
        assert!(csr.owns(1) && csr.owns(2));
        assert_eq!(csr.degree(1), 2);
        let nbrs: Vec<VertexId> = csr.neighbours(1).map(|(_, n, _)| n).collect();
        assert!(nbrs.contains(&0) && nbrs.contains(&2));
    }

    #[test]
    fn weights_travel_with_columns() {
        let csr = Csr::full(&triangle());
        for (_, n, w) in csr.neighbours(0) {
            match n {
                1 => assert_eq!(w, 0.1),
                2 => assert_eq!(w, 0.3),
                _ => panic!("unexpected neighbour {n}"),
            }
        }
    }

    #[test]
    fn sorted_rows_are_sorted() {
        props("csr row sorting", 50, |g| {
            let n = g.usize_in(2, 40) as u32;
            let mut el = EdgeList::with_vertices(n);
            let m = g.usize_in(1, 120);
            for _ in 0..m {
                let u = g.u64_below(n as u64) as u32;
                let v = g.u64_below(n as u64) as u32;
                if u != v {
                    el.push(u, v, g.f64());
                }
            }
            let mut csr = Csr::full(&el);
            csr.sort_rows_by_neighbour();
            for v in 0..n {
                let cols: Vec<u32> = csr.neighbours(v).map(|(_, c, _)| c).collect();
                assert!(cols.windows(2).all(|w| w[0] <= w[1]));
            }
        });
    }

    #[test]
    fn mapped_partition_rows_cover_full_graph() {
        use crate::graph::partition::{Partition, PartitionSpec};
        let mut rng = Xoshiro256::seed_from_u64(101);
        let n = 40u32;
        let mut el = EdgeList::with_vertices(n);
        for _ in 0..150 {
            let u = rng.next_below(n as u64) as u32;
            let v = rng.next_below(n as u64) as u32;
            if u != v {
                el.push(u, v, rng.next_weight());
            }
        }
        let full = Csr::full(&el);
        // Interleaved owner map: 0,1,2,0,1,2,... (maximally non-contiguous).
        let map: Vec<u32> = (0..n).map(|v| v % 3).collect();
        let part =
            Partition::build(&PartitionSpec::Explicit(std::sync::Arc::new(map)), &el, n, 3)
                .unwrap();
        let blocks: Vec<Csr> = (0..3).map(|r| Csr::from_partition(&el, &part, r)).collect();
        assert_eq!(full.nnz(), blocks.iter().map(|b| b.nnz()).sum::<usize>());
        for v in 0..n {
            let b = &blocks[(v % 3) as usize];
            assert!(b.owns(v));
            assert_eq!(b.degree(v), full.degree(v), "vertex {v}");
            assert_eq!(b.vertex_of(b.row_of(v) as u32), v, "row round-trip for {v}");
            // Same neighbour multiset as the full CSR row.
            let mut got: Vec<VertexId> = b.neighbours(v).map(|(_, c, _)| c).collect();
            let mut want: Vec<VertexId> = full.neighbours(v).map(|(_, c, _)| c).collect();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want);
            // Other ranks must not own it.
            for r in 0..3u32 {
                if r != v % 3 {
                    assert!(!blocks[r as usize].owns(v));
                }
            }
        }
    }

    #[test]
    fn from_partition_contiguous_matches_from_edges() {
        use crate::graph::partition::Partition;
        let el = triangle();
        let part = Partition::block(3, 2);
        for r in 0..2 {
            let a = Csr::from_partition(&el, &part, r);
            let b = Csr::from_edges(&el, part.first_vertex(r), part.n_local(r));
            assert_eq!(a.nnz(), b.nnz());
            assert_eq!(a.rows(), b.rows());
            assert_eq!(a.first_vertex(), b.first_vertex());
        }
    }

    #[test]
    fn partitioned_blocks_cover_full_graph() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let n = 64u32;
        let mut el = EdgeList::with_vertices(n);
        for _ in 0..300 {
            let u = rng.next_below(n as u64) as u32;
            let v = rng.next_below(n as u64) as u32;
            if u != v {
                el.push(u, v, rng.next_weight());
            }
        }
        let full = Csr::full(&el);
        let a = Csr::from_edges(&el, 0, 32);
        let b = Csr::from_edges(&el, 32, 32);
        assert_eq!(full.nnz(), a.nnz() + b.nnz());
        for v in 0..n {
            let block = if v < 32 { &a } else { &b };
            assert_eq!(block.degree(v), full.degree(v));
        }
    }
}
