//! Edge-list I/O: a simple text format (one `u v w` per line, `#`-comments)
//! and a compact little-endian binary format, for saving generated
//! workloads and replaying them across runs.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::EdgeList;

const BINARY_MAGIC: &[u8; 8] = b"GHSMSTG1";

/// Write the text format.
pub fn write_text(g: &EdgeList, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(w, "# ghs-mst edge list: n_vertices n_edges, then u v w per line")?;
    writeln!(w, "{} {}", g.n_vertices, g.n_edges())?;
    for e in &g.edges {
        // {:e} round-trips f64 exactly via scientific notation with enough digits.
        writeln!(w, "{} {} {:.17e}", e.u, e.v, e.w)?;
    }
    Ok(())
}

/// Read the text format.
pub fn read_text(path: &Path) -> Result<EdgeList> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut lines = r.lines();
    let header = loop {
        match lines.next() {
            Some(line) => {
                let line = line?;
                let t = line.trim();
                if !t.is_empty() && !t.starts_with('#') {
                    break t.to_string();
                }
            }
            None => bail!("empty edge-list file"),
        }
    };
    let mut parts = header.split_whitespace();
    let n: u32 = parts.next().context("missing n_vertices")?.parse()?;
    let m: usize = parts.next().context("missing n_edges")?.parse()?;
    let mut g = EdgeList::with_vertices(n);
    g.edges.reserve(m);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it.next().context("missing u")?.parse()?;
        let v: u32 = it.next().context("missing v")?.parse()?;
        let w: f64 = it.next().context("missing w")?.parse()?;
        g.push(u, v, w);
    }
    if g.n_edges() != m {
        bail!("edge count mismatch: header {m}, found {}", g.n_edges());
    }
    Ok(g)
}

/// Write the binary format (magic, n, m, then (u32, u32, f64) triples LE).
pub fn write_binary(g: &EdgeList, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(BINARY_MAGIC)?;
    w.write_all(&g.n_vertices.to_le_bytes())?;
    w.write_all(&(g.n_edges() as u64).to_le_bytes())?;
    for e in &g.edges {
        w.write_all(&e.u.to_le_bytes())?;
        w.write_all(&e.v.to_le_bytes())?;
        w.write_all(&e.w.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary format.
pub fn read_binary(path: &Path) -> Result<EdgeList> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BINARY_MAGIC {
        bail!("bad magic: not a ghs-mst binary edge list");
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4);
    r.read_exact(&mut b8)?;
    let m = u64::from_le_bytes(b8) as usize;
    let mut g = EdgeList::with_vertices(n);
    g.edges.reserve(m);
    for _ in 0..m {
        r.read_exact(&mut b4)?;
        let u = u32::from_le_bytes(b4);
        r.read_exact(&mut b4)?;
        let v = u32::from_le_bytes(b4);
        r.read_exact(&mut b8)?;
        let w = f64::from_le_bytes(b8);
        g.push(u, v, w);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ghs_mst_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn text_roundtrip_exact() {
        let g = generate(GraphFamily::Rmat, 6, 3);
        let p = tmp("roundtrip.txt");
        write_text(&g, &p).unwrap();
        let g2 = read_text(&p).unwrap();
        assert_eq!(g.n_vertices, g2.n_vertices);
        assert_eq!(g.n_edges(), g2.n_edges());
        for (a, b) in g.edges.iter().zip(&g2.edges) {
            assert_eq!((a.u, a.v), (b.u, b.v));
            assert_eq!(a.w, b.w, "weights must round-trip bit-exactly");
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let g = generate(GraphFamily::Random, 7, 4);
        let p = tmp("roundtrip.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g.n_vertices, g2.n_vertices);
        for (a, b) in g.edges.iter().zip(&g2.edges) {
            assert_eq!((a.u, a.v, a.w.to_bits()), (b.u, b.v, b.w.to_bits()));
        }
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a graph").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn text_rejects_truncation() {
        let g = generate(GraphFamily::Rmat, 4, 5);
        let p = tmp("trunc.txt");
        write_text(&g, &p).unwrap();
        let contents = std::fs::read_to_string(&p).unwrap();
        let truncated: String = contents.lines().take(10).collect::<Vec<_>>().join("\n");
        std::fs::write(&p, truncated).unwrap();
        assert!(read_text(&p).is_err());
    }
}
