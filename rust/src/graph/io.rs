//! Edge-list I/O: a simple text format (one `u v w` per line, `#`-comments),
//! a compact little-endian binary format, a DIMACS-style `.gr` /
//! whitespace edge-list reader for real-world graphs, and owner-map files
//! for replayable explicit partitions.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::EdgeList;

const BINARY_MAGIC: &[u8; 8] = b"GHSMSTG1";

/// Write the text format.
pub fn write_text(g: &EdgeList, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(w, "# ghs-mst edge list: n_vertices n_edges, then u v w per line")?;
    writeln!(w, "{} {}", g.n_vertices, g.n_edges())?;
    for e in &g.edges {
        // {:e} round-trips f64 exactly via scientific notation with enough digits.
        writeln!(w, "{} {} {:.17e}", e.u, e.v, e.w)?;
    }
    Ok(())
}

/// Read the text format.
pub fn read_text(path: &Path) -> Result<EdgeList> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut lines = r.lines();
    let header = loop {
        match lines.next() {
            Some(line) => {
                let line = line?;
                let t = line.trim();
                if !t.is_empty() && !t.starts_with('#') {
                    break t.to_string();
                }
            }
            None => bail!("empty edge-list file"),
        }
    };
    let mut parts = header.split_whitespace();
    let n: u32 = parts.next().context("missing n_vertices")?.parse()?;
    let m: usize = parts.next().context("missing n_edges")?.parse()?;
    let mut g = EdgeList::with_vertices(n);
    g.edges.reserve(m);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it.next().context("missing u")?.parse()?;
        let v: u32 = it.next().context("missing v")?.parse()?;
        let w: f64 = it.next().context("missing w")?.parse()?;
        g.push(u, v, w);
    }
    if g.n_edges() != m {
        bail!("edge count mismatch: header {m}, found {}", g.n_edges());
    }
    Ok(g)
}

/// Read a DIMACS-style `.gr` file or a bare whitespace edge list — the
/// door for real-world graphs (road networks, web crawls) next to the
/// synthetic generators.
///
/// Two dialects, auto-detected per line:
///
/// * **DIMACS** (9th DIMACS Implementation Challenge): `c` comment lines,
///   a `p sp <n> <m>` problem line, and `a <u> <v> [w]` (or `e ...`) arc
///   lines with **1-indexed** vertices. Arcs listed in both directions
///   collapse to a single undirected edge in
///   [`crate::graph::preprocess::preprocess`].
/// * **Bare edge list**: `<u> <v> [w]` per line with **0-indexed**
///   vertices, `#`/`c` comments; the vertex count is inferred as
///   `max id + 1`.
///
/// Missing weights default to 1.0 — GHS tie-breaks equal weights through
/// the unique `special_id`, so integer/unit-weight graphs are fine.
pub fn read_gr(path: &Path) -> Result<EdgeList> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut header: Option<(u64, usize)> = None; // (n, m) from a `p` line
    let mut edges: Vec<(u64, u64, f64)> = Vec::new();
    let mut max_id = 0u64;
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let lineno = i + 1;
        let mut it = t.split_whitespace();
        let first = it.next().expect("non-empty line");
        let parse_edge = |it: &mut std::str::SplitWhitespace<'_>,
                          one_indexed: bool|
         -> Result<(u64, u64, f64)> {
            let u: u64 = it
                .next()
                .with_context(|| format!("line {lineno}: missing source vertex"))?
                .parse()
                .with_context(|| format!("line {lineno}: bad source vertex"))?;
            let v: u64 = it
                .next()
                .with_context(|| format!("line {lineno}: missing target vertex"))?
                .parse()
                .with_context(|| format!("line {lineno}: bad target vertex"))?;
            let w: f64 = match it.next() {
                Some(s) => {
                    s.parse().with_context(|| format!("line {lineno}: bad weight `{s}`"))?
                }
                None => 1.0,
            };
            if one_indexed {
                if u == 0 || v == 0 {
                    bail!("line {lineno}: DIMACS vertex ids are 1-indexed, found 0");
                }
                Ok((u - 1, v - 1, w))
            } else {
                Ok((u, v, w))
            }
        };
        match first {
            "c" => continue,
            "p" => {
                // `p sp <n> <m>` / `p edge <n> <m>` / `p <n> <m>`.
                let nums: Vec<u64> = it.filter_map(|s| s.parse().ok()).collect();
                if nums.len() < 2 {
                    bail!("line {lineno}: malformed problem line `{t}`");
                }
                header = Some((nums[0], nums[1] as usize));
            }
            "a" | "e" => {
                let e = parse_edge(&mut it, true)?;
                max_id = max_id.max(e.0).max(e.1);
                edges.push(e);
            }
            _ => {
                // Bare dialect: `first` is the (0-indexed) source vertex.
                let mut full = t.split_whitespace();
                let e = parse_edge(&mut full, false)?;
                max_id = max_id.max(e.0).max(e.1);
                edges.push(e);
            }
        }
    }
    let n = match header {
        Some((n, m)) => {
            if edges.len() != m {
                bail!("edge count mismatch: problem line declares {m}, found {}", edges.len());
            }
            n
        }
        None => {
            if edges.is_empty() {
                bail!("empty edge-list file (no problem line, no edges)");
            }
            max_id + 1
        }
    };
    if n > u32::MAX as u64 || (!edges.is_empty() && max_id >= n) {
        bail!("vertex id {max_id} out of range for {n} declared vertices");
    }
    let mut g = EdgeList::with_vertices(n as u32);
    g.edges.reserve(edges.len());
    for (u, v, w) in edges {
        g.push(u as u32, v as u32, w);
    }
    Ok(g)
}

/// Read any supported on-disk graph format, dispatching on the file
/// extension: `.gr` / `.dimacs` → [`read_gr`], `.bin` → [`read_binary`],
/// anything else → [`read_text`].
pub fn read_auto(path: &Path) -> Result<EdgeList> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("gr") | Some("dimacs") => read_gr(path),
        Some("bin") => read_binary(path),
        _ => read_text(path),
    }
}

/// Write an owner map for `PartitionSpec::Explicit`: one rank id per
/// line, in vertex-id order.
pub fn write_owner_map(owners: &[u32], path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(w, "# ghs-mst owner map: line i = owning rank of vertex i")?;
    for r in owners {
        writeln!(w, "{r}")?;
    }
    Ok(())
}

/// Read an owner map (one rank id per line, `#` comments and blank lines
/// ignored). Validation against the graph's vertex count and rank count
/// happens when the partition is built.
pub fn read_owner_map(path: &Path) -> Result<Vec<u32>> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut owners = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        owners.push(
            t.parse::<u32>()
                .with_context(|| format!("line {}: bad rank id `{t}` in owner map", i + 1))?,
        );
    }
    Ok(owners)
}

/// Write the binary format (magic, n, m, then (u32, u32, f64) triples LE).
pub fn write_binary(g: &EdgeList, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(BINARY_MAGIC)?;
    w.write_all(&g.n_vertices.to_le_bytes())?;
    w.write_all(&(g.n_edges() as u64).to_le_bytes())?;
    for e in &g.edges {
        w.write_all(&e.u.to_le_bytes())?;
        w.write_all(&e.v.to_le_bytes())?;
        w.write_all(&e.w.to_le_bytes())?;
    }
    Ok(())
}

/// Read the binary format.
pub fn read_binary(path: &Path) -> Result<EdgeList> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BINARY_MAGIC {
        bail!("bad magic: not a ghs-mst binary edge list");
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let n = u32::from_le_bytes(b4);
    r.read_exact(&mut b8)?;
    let m = u64::from_le_bytes(b8) as usize;
    let mut g = EdgeList::with_vertices(n);
    g.edges.reserve(m);
    for _ in 0..m {
        r.read_exact(&mut b4)?;
        let u = u32::from_le_bytes(b4);
        r.read_exact(&mut b4)?;
        let v = u32::from_le_bytes(b4);
        r.read_exact(&mut b8)?;
        let w = f64::from_le_bytes(b8);
        g.push(u, v, w);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ghs_mst_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn text_roundtrip_exact() {
        let g = generate(GraphFamily::Rmat, 6, 3);
        let p = tmp("roundtrip.txt");
        write_text(&g, &p).unwrap();
        let g2 = read_text(&p).unwrap();
        assert_eq!(g.n_vertices, g2.n_vertices);
        assert_eq!(g.n_edges(), g2.n_edges());
        for (a, b) in g.edges.iter().zip(&g2.edges) {
            assert_eq!((a.u, a.v), (b.u, b.v));
            assert_eq!(a.w, b.w, "weights must round-trip bit-exactly");
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let g = generate(GraphFamily::Random, 7, 4);
        let p = tmp("roundtrip.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g.n_vertices, g2.n_vertices);
        for (a, b) in g.edges.iter().zip(&g2.edges) {
            assert_eq!((a.u, a.v, a.w.to_bits()), (b.u, b.v, b.w.to_bits()));
        }
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a graph").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn gr_dimacs_dialect() {
        let p = tmp("sample.gr");
        std::fs::write(
            &p,
            "c 4-vertex road-network-style sample\n\
             p sp 4 5\n\
             a 1 2 0.5\n\
             a 2 1 0.5\n\
             a 2 3 1.25\n\
             a 3 4 2\n\
             a 1 4 7\n",
        )
        .unwrap();
        let g = read_gr(&p).unwrap();
        assert_eq!(g.n_vertices, 4);
        assert_eq!(g.n_edges(), 5, "raw arcs kept; preprocess dedups");
        // 1-indexed ids shifted down.
        assert_eq!((g.edges[0].u, g.edges[0].v, g.edges[0].w), (0, 1, 0.5));
        let (clean, stats) = crate::graph::preprocess::preprocess(&g);
        assert_eq!(stats.multi_edges_removed, 1, "the a 1 2 / a 2 1 pair collapses");
        assert_eq!(clean.n_edges(), 4);
        // Feeds the engine end-to-end.
        let run = crate::ghs::engine::run_ghs(
            &clean,
            crate::ghs::config::GhsConfig::final_version(2),
        )
        .unwrap();
        assert_eq!(run.forest.n_components, 1);
        assert_eq!(run.forest.edges.len(), 3);
    }

    #[test]
    fn gr_bare_dialect_zero_indexed_and_default_weight() {
        let p = tmp("bare.gr");
        std::fs::write(&p, "# bare whitespace edge list\n0 1 0.25\n1 2\nc trailing comment\n")
            .unwrap();
        let g = read_gr(&p).unwrap();
        assert_eq!(g.n_vertices, 3, "inferred as max id + 1");
        assert_eq!(g.n_edges(), 2);
        assert_eq!(g.edges[1].w, 1.0, "missing weight defaults to 1.0");
    }

    #[test]
    fn gr_rejects_malformed_inputs() {
        let zero = tmp("zero.gr");
        std::fs::write(&zero, "p sp 3 1\na 0 1 0.5\n").unwrap();
        assert!(read_gr(&zero).is_err(), "DIMACS ids are 1-indexed");
        let count = tmp("count.gr");
        std::fs::write(&count, "p sp 3 2\na 1 2 0.5\n").unwrap();
        assert!(read_gr(&count).is_err(), "declared m must match");
        let range = tmp("range.gr");
        std::fs::write(&range, "p sp 2 1\na 1 3 0.5\n").unwrap();
        assert!(read_gr(&range).is_err(), "id beyond declared n");
        let junk = tmp("junk.gr");
        std::fs::write(&junk, "0 one 0.5\n").unwrap();
        assert!(read_gr(&junk).is_err());
    }

    #[test]
    fn read_auto_dispatches_on_extension() {
        let g = generate(GraphFamily::Random, 5, 8);
        let pt = tmp("auto.txt");
        write_text(&g, &pt).unwrap();
        assert_eq!(read_auto(&pt).unwrap().n_edges(), g.n_edges());
        let pb = tmp("auto.bin");
        write_binary(&g, &pb).unwrap();
        assert_eq!(read_auto(&pb).unwrap().n_edges(), g.n_edges());
        let pg = tmp("auto.gr");
        std::fs::write(&pg, "p sp 2 1\na 1 2 0.5\n").unwrap();
        assert_eq!(read_auto(&pg).unwrap().n_vertices, 2);
    }

    #[test]
    fn owner_map_roundtrip() {
        let owners: Vec<u32> = vec![3, 0, 1, 1, 2, 0];
        let p = tmp("owners.txt");
        write_owner_map(&owners, &p).unwrap();
        assert_eq!(read_owner_map(&p).unwrap(), owners);
        // Comments and blanks are tolerated; garbage is not.
        std::fs::write(&p, "# map\n\n0\n1\n").unwrap();
        assert_eq!(read_owner_map(&p).unwrap(), vec![0, 1]);
        std::fs::write(&p, "0\nnope\n").unwrap();
        assert!(read_owner_map(&p).is_err());
    }

    #[test]
    fn text_rejects_truncation() {
        let g = generate(GraphFamily::Rmat, 4, 5);
        let p = tmp("trunc.txt");
        write_text(&g, &p).unwrap();
        let contents = std::fs::read_to_string(&p).unwrap();
        let truncated: String = contents.lines().take(10).collect::<Vec<_>>().join("\n");
        std::fs::write(&p, truncated).unwrap();
        assert!(read_text(&p).is_err());
    }
}
