//! Vertex-to-rank partitioning subsystem.
//!
//! The paper distributes vertices "sequentially in blocks among the
//! processes" (§3); that is [`BlockPartition`], still the default. Block
//! partitioning is the known weak point on skewed (R-MAT-like) inputs,
//! where a handful of hub-owning ranks absorb most Test/Connect traffic,
//! so the subsystem is pluggable: a [`PartitionSpec`] in the engine config
//! selects the strategy, [`Partition::build`] materializes it over the
//! concrete graph, and [`PartitionStats`] reports its quality
//! (vertex/edge balance, edge cut) so comm costs can be correlated with
//! cut quality.
//!
//! Strategies:
//! * **Block** — the paper's contiguous equal-vertex-count blocks
//!   (bit-for-bit the historical behavior).
//! * **DegreeBalanced** — contiguous chunks whose boundaries are chosen so
//!   per-rank *edge* counts (adjacency entries), not vertex counts, are
//!   balanced.
//! * **HubScatter** — skew-aware: the top-k highest-degree vertices are
//!   spread round-robin across ranks, the rest block-filled. Breaks
//!   contiguity, which is why `local_index` is part of the abstraction.
//! * **Explicit** — an arbitrary owner map (loadable from a file via
//!   [`crate::graph::io::read_owner_map`]) for replayable experiments.
//! * **Multilevel** — edge-cut-minimizing coarsen/partition/refine
//!   ([`multilevel`]): seeded heavy-edge-matching coarsening, greedy
//!   balanced k-way assignment on the coarsest graph, KL/FM-style
//!   boundary refinement under a configurable balance factor ε, with a
//!   never-worse-than-block fallback. The only strategy that reads
//!   adjacency structure rather than ids/degrees — the traffic lever on
//!   scrambled inputs.
//!
//! A [`Partition`] is cheap to clone: contiguous variants are a couple of
//! words, mapped variants share their tables behind an `Arc`.

pub mod multilevel;
pub mod stats;
mod strategies;

pub use stats::PartitionStats;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::graph::{EdgeList, VertexId};

/// Block distribution of `n_vertices` over `n_ranks`: the first
/// `n % p` ranks get `ceil(n/p)` vertices, the rest `floor(n/p)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockPartition {
    n_vertices: u32,
    n_ranks: u32,
}

impl BlockPartition {
    /// Create a partition; `n_ranks >= 1`.
    pub fn new(n_vertices: u32, n_ranks: u32) -> Self {
        assert!(n_ranks >= 1, "need at least one rank");
        Self { n_vertices, n_ranks }
    }

    /// Number of ranks.
    pub fn n_ranks(&self) -> u32 {
        self.n_ranks
    }

    /// Total vertices.
    pub fn n_vertices(&self) -> u32 {
        self.n_vertices
    }

    /// First vertex owned by `rank`.
    pub fn first_vertex(&self, rank: u32) -> VertexId {
        debug_assert!(rank < self.n_ranks);
        let n = self.n_vertices as u64;
        let p = self.n_ranks as u64;
        let r = rank as u64;
        let base = n / p;
        let extra = n % p;
        (r * base + r.min(extra)) as u32
    }

    /// Number of vertices owned by `rank`.
    pub fn block_size(&self, rank: u32) -> u32 {
        let n = self.n_vertices as u64;
        let p = self.n_ranks as u64;
        let base = (n / p) as u32;
        if (rank as u64) < n % p {
            base + 1
        } else {
            base
        }
    }

    /// Which rank owns vertex `v`?
    pub fn owner(&self, v: VertexId) -> u32 {
        debug_assert!(v < self.n_vertices);
        let n = self.n_vertices as u64;
        let p = self.n_ranks as u64;
        let base = n / p;
        let extra = n % p;
        let v = v as u64;
        let boundary = extra * (base + 1);
        if v < boundary {
            (v / (base + 1)) as u32
        } else {
            (extra + (v - boundary) / base.max(1)) as u32
        }
    }
}

/// Partitioning strategy selector — lives in
/// [`GhsConfig`](crate::ghs::config::GhsConfig) and is materialized into a
/// [`Partition`] by the engines via [`Partition::build`].
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionSpec {
    /// The paper's contiguous blocks (default; reproduces historical
    /// results exactly).
    Block,
    /// Contiguous chunks balancing per-rank adjacency-entry counts.
    DegreeBalanced,
    /// Top-k hubs round-robin across ranks, the rest block-filled.
    /// `top_k == 0` picks `4 * n_ranks` hubs automatically.
    HubScatter { top_k: u32 },
    /// An explicit owner map (`map[v]` = owning rank of vertex `v`).
    Explicit(Arc<Vec<u32>>),
    /// Edge-cut-minimizing multilevel coarsen/partition/refine with
    /// balance factor `eps` (ranks may exceed the ideal vertex count by
    /// `(eps - 1)`) and a matching-order `seed` (see [`multilevel`]).
    Multilevel { eps: f64, seed: u64 },
}

impl Default for PartitionSpec {
    fn default() -> Self {
        PartitionSpec::Block
    }
}

impl PartitionSpec {
    /// The multilevel strategy at its defaults (ε = 1.05, fixed seed).
    pub fn multilevel() -> Self {
        Self::Multilevel { eps: multilevel::DEFAULT_EPS, seed: multilevel::DEFAULT_SEED }
    }

    /// Parse a strategy name (`block` / `degree` / `hub` /
    /// `multilevel[:eps]` with `eps >= 1.0`). File-backed explicit maps
    /// are handled by the CLI (`file:<path>`), which loads the map and
    /// wraps it in [`PartitionSpec::Explicit`].
    pub fn parse(s: &str) -> Option<Self> {
        let lower = s.to_ascii_lowercase();
        if let Some(rest) = lower.strip_prefix("multilevel") {
            if rest.is_empty() {
                return Some(Self::multilevel());
            }
            let eps: f64 = rest.strip_prefix(':')?.parse().ok()?;
            if !eps.is_finite() || eps < 1.0 {
                return None;
            }
            return Some(Self::Multilevel { eps, seed: multilevel::DEFAULT_SEED });
        }
        match lower.as_str() {
            "block" => Some(Self::Block),
            "degree" | "degree-balanced" => Some(Self::DegreeBalanced),
            "hub" | "hub-scatter" => Some(Self::HubScatter { top_k: 0 }),
            _ => None,
        }
    }

    /// Display name.
    pub fn label(&self) -> &'static str {
        match self {
            Self::Block => "block",
            Self::DegreeBalanced => "degree",
            Self::HubScatter { .. } => "hub",
            Self::Explicit(_) => "explicit",
            Self::Multilevel { .. } => "multilevel",
        }
    }
}

/// A contiguous partition with arbitrary boundaries: rank `r` owns
/// `[bounds[r], bounds[r+1])`. Used by the degree-balanced strategy.
#[derive(Debug, Clone)]
pub struct ContiguousPartition {
    /// Monotone boundaries, length `n_ranks + 1`, `bounds[0] == 0` and
    /// `bounds[n_ranks] == n_vertices`.
    bounds: Arc<Vec<u32>>,
}

impl ContiguousPartition {
    /// Wrap a boundary vector (must be monotone, first 0, last n).
    pub fn new(bounds: Vec<u32>) -> Self {
        debug_assert!(bounds.len() >= 2);
        debug_assert_eq!(bounds[0], 0);
        debug_assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds not monotone");
        Self { bounds: Arc::new(bounds) }
    }

    fn n_ranks(&self) -> u32 {
        (self.bounds.len() - 1) as u32
    }

    fn n_vertices(&self) -> u32 {
        *self.bounds.last().unwrap()
    }

    #[inline]
    fn owner(&self, v: VertexId) -> u32 {
        debug_assert!(v < self.n_vertices());
        // Number of boundaries <= v, minus one; empty ranks (repeated
        // boundaries) resolve to the last rank starting at that boundary,
        // which is the one owning the non-empty half-open range.
        (self.bounds.partition_point(|&b| b <= v) - 1) as u32
    }
}

/// Shared tables of a non-contiguous (mapped) partition. One instance per
/// run, shared by the partition handle and every rank's CSR via `Arc`.
#[derive(Debug)]
pub struct MappedData {
    /// Owner rank of each vertex (length `n_vertices`).
    pub owner: Vec<u32>,
    /// Local row index of each vertex on its owning rank (length
    /// `n_vertices`).
    pub local: Vec<u32>,
    /// Vertices owned by each rank in ascending id order (the inverse of
    /// `local`: `rank_vertices[r][local[v]] == v` when `owner[v] == r`).
    pub rank_vertices: Vec<Vec<VertexId>>,
}

impl MappedData {
    /// Build the local/rank_vertices tables from an owner map. Owners must
    /// already be `< n_ranks`.
    pub fn from_owner_map(owner: Vec<u32>, n_ranks: u32) -> Self {
        let mut rank_vertices: Vec<Vec<VertexId>> = vec![Vec::new(); n_ranks as usize];
        for (v, &r) in owner.iter().enumerate() {
            debug_assert!(r < n_ranks);
            rank_vertices[r as usize].push(v as u32);
        }
        let mut local = vec![0u32; owner.len()];
        for vs in &rank_vertices {
            for (i, &v) in vs.iter().enumerate() {
                local[v as usize] = i as u32;
            }
        }
        Self { owner, local, rank_vertices }
    }
}

/// A non-contiguous partition backed by shared [`MappedData`] tables.
#[derive(Debug, Clone)]
pub struct MappedPartition {
    data: Arc<MappedData>,
}

impl MappedPartition {
    /// Wrap built tables.
    pub fn new(data: MappedData) -> Self {
        Self { data: Arc::new(data) }
    }
}

/// The vertex-to-rank assignment of one run. Enum dispatch keeps the hot
/// `owner()` call (every remote send) free of virtual calls; all variants
/// are cheap to clone (`Copy`-sized or `Arc`-shared).
#[derive(Debug, Clone)]
pub enum Partition {
    /// The paper's arithmetic block layout.
    Block(BlockPartition),
    /// Contiguous with explicit boundaries (degree-balanced).
    Contiguous(ContiguousPartition),
    /// Non-contiguous owner map (hub-scatter, explicit).
    Mapped(MappedPartition),
}

impl Partition {
    /// The default block partition (paper §3).
    pub fn block(n_vertices: u32, n_ranks: u32) -> Self {
        Partition::Block(BlockPartition::new(n_vertices, n_ranks))
    }

    /// Materialize `spec` over a concrete graph. `n_vertices` is passed
    /// explicitly because the engines partition `g.n_vertices.max(1)`
    /// (a rank-0 placeholder row for empty graphs).
    pub fn build(
        spec: &PartitionSpec,
        g: &EdgeList,
        n_vertices: u32,
        n_ranks: u32,
    ) -> Result<Self> {
        if n_ranks == 0 {
            bail!("need at least one rank");
        }
        Ok(match spec {
            PartitionSpec::Block => Self::block(n_vertices, n_ranks),
            PartitionSpec::DegreeBalanced => {
                Partition::Contiguous(strategies::degree_balanced(g, n_vertices, n_ranks))
            }
            PartitionSpec::HubScatter { top_k } => {
                Partition::Mapped(strategies::hub_scatter(g, n_vertices, n_ranks, *top_k))
            }
            PartitionSpec::Explicit(map) => {
                Partition::Mapped(strategies::explicit(map, n_vertices, n_ranks)?)
            }
            PartitionSpec::Multilevel { eps, seed } => {
                Partition::Mapped(multilevel::multilevel(g, n_vertices, n_ranks, *eps, *seed))
            }
        })
    }

    /// Number of ranks.
    pub fn n_ranks(&self) -> u32 {
        match self {
            Partition::Block(b) => b.n_ranks(),
            Partition::Contiguous(c) => c.n_ranks(),
            Partition::Mapped(m) => m.data.rank_vertices.len() as u32,
        }
    }

    /// Total vertices.
    pub fn n_vertices(&self) -> u32 {
        match self {
            Partition::Block(b) => b.n_vertices(),
            Partition::Contiguous(c) => c.n_vertices(),
            Partition::Mapped(m) => m.data.owner.len() as u32,
        }
    }

    /// Which rank owns vertex `v`? (Hot: called for every sent message.)
    #[inline]
    pub fn owner(&self, v: VertexId) -> u32 {
        match self {
            Partition::Block(b) => b.owner(v),
            Partition::Contiguous(c) => c.owner(v),
            Partition::Mapped(m) => m.data.owner[v as usize],
        }
    }

    /// Local row index of `v` on its owning rank. Together with
    /// [`Self::owner`] this forms a bijection `v <-> (rank, row)` tiling
    /// `[0, n_vertices)`.
    #[inline]
    pub fn local_index(&self, v: VertexId) -> u32 {
        match self {
            Partition::Block(b) => v - b.first_vertex(b.owner(v)),
            Partition::Contiguous(c) => v - c.bounds[c.owner(v) as usize],
            Partition::Mapped(m) => m.data.local[v as usize],
        }
    }

    /// Number of vertices owned by `rank`.
    pub fn n_local(&self, rank: u32) -> u32 {
        match self {
            Partition::Block(b) => b.block_size(rank),
            Partition::Contiguous(c) => c.bounds[rank as usize + 1] - c.bounds[rank as usize],
            Partition::Mapped(m) => m.data.rank_vertices[rank as usize].len() as u32,
        }
    }

    /// Global id of `rank`'s `row`-th local vertex (inverse of
    /// [`Self::local_index`] on that rank).
    #[inline]
    pub fn vertex_of(&self, rank: u32, row: u32) -> VertexId {
        debug_assert!(row < self.n_local(rank));
        match self {
            Partition::Block(b) => b.first_vertex(rank) + row,
            Partition::Contiguous(c) => c.bounds[rank as usize] + row,
            Partition::Mapped(m) => m.data.rank_vertices[rank as usize][row as usize],
        }
    }

    /// First vertex owned by `rank` (lowest id; contiguous variants: the
    /// block start). Meaningful only when `n_local(rank) > 0`.
    pub fn first_vertex(&self, rank: u32) -> VertexId {
        match self {
            Partition::Block(b) => b.first_vertex(rank),
            Partition::Contiguous(c) => c.bounds[rank as usize],
            Partition::Mapped(m) => {
                m.data.rank_vertices[rank as usize].first().copied().unwrap_or(0)
            }
        }
    }

    /// All vertices owned by `rank`, ascending (row order).
    pub fn vertices_of(&self, rank: u32) -> Vec<VertexId> {
        (0..self.n_local(rank)).map(|row| self.vertex_of(rank, row)).collect()
    }

    /// The shared mapped tables, when this partition is non-contiguous
    /// (used by [`crate::graph::csr::Csr`] to share the owner/local maps).
    pub fn mapped_data(&self) -> Option<&Arc<MappedData>> {
        match self {
            Partition::Mapped(m) => Some(&m.data),
            _ => None,
        }
    }

    /// Representation kind (diagnostics).
    pub fn kind_label(&self) -> &'static str {
        match self {
            Partition::Block(_) => "block",
            Partition::Contiguous(_) => "contiguous",
            Partition::Mapped(_) => "mapped",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::props;

    #[test]
    fn even_split() {
        let p = BlockPartition::new(100, 4);
        for r in 0..4 {
            assert_eq!(p.block_size(r), 25);
            assert_eq!(p.first_vertex(r), r * 25);
        }
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(24), 0);
        assert_eq!(p.owner(25), 1);
        assert_eq!(p.owner(99), 3);
    }

    #[test]
    fn uneven_split() {
        let p = BlockPartition::new(10, 3); // sizes 4, 3, 3
        assert_eq!(p.block_size(0), 4);
        assert_eq!(p.block_size(1), 3);
        assert_eq!(p.block_size(2), 3);
        assert_eq!(p.first_vertex(0), 0);
        assert_eq!(p.first_vertex(1), 4);
        assert_eq!(p.first_vertex(2), 7);
    }

    #[test]
    fn more_ranks_than_vertices() {
        let p = BlockPartition::new(3, 8);
        let total: u32 = (0..8).map(|r| p.block_size(r)).sum();
        assert_eq!(total, 3);
        for v in 0..3 {
            let r = p.owner(v);
            assert!(v >= p.first_vertex(r));
            assert!(v < p.first_vertex(r) + p.block_size(r));
        }
    }

    #[test]
    fn owner_and_blocks_agree() {
        props("partition owner/block agreement", 200, |g| {
            let n = g.usize_in(1, 10_000) as u32;
            let p_ranks = g.usize_in(1, 64) as u32;
            let p = BlockPartition::new(n, p_ranks);
            // Blocks tile [0, n).
            let mut covered = 0u32;
            for r in 0..p_ranks {
                assert_eq!(p.first_vertex(r), covered);
                covered += p.block_size(r);
            }
            assert_eq!(covered, n);
            // Spot-check owner() consistency on random vertices.
            for _ in 0..20 {
                if n == 0 {
                    break;
                }
                let v = g.u64_below(n as u64) as u32;
                let r = p.owner(v);
                assert!(v >= p.first_vertex(r) && v < p.first_vertex(r) + p.block_size(r));
            }
        });
    }

    #[test]
    fn block_variant_matches_legacy_arithmetic() {
        // `Partition::Block` must be bit-for-bit the historical layout.
        props("Partition::Block == BlockPartition", 100, |g| {
            let n = g.usize_in(1, 5_000) as u32;
            let p_ranks = g.usize_in(1, 64) as u32;
            let legacy = BlockPartition::new(n, p_ranks);
            let part = Partition::block(n, p_ranks);
            for r in 0..p_ranks {
                assert_eq!(part.n_local(r), legacy.block_size(r));
                assert_eq!(part.first_vertex(r), legacy.first_vertex(r));
            }
            for _ in 0..30 {
                let v = g.u64_below(n as u64) as u32;
                assert_eq!(part.owner(v), legacy.owner(v));
                assert_eq!(part.local_index(v), v - legacy.first_vertex(legacy.owner(v)));
            }
        });
    }

    /// Random simple-ish graph for the bijection sweep (self-loops are
    /// irrelevant to partitioning; strategies only read degrees).
    fn random_graph(g: &mut crate::util::minitest::Gen, n: u32) -> EdgeList {
        let mut el = EdgeList::with_vertices(n);
        if n >= 2 {
            for _ in 0..g.usize_in(0, 4 * n as usize) {
                let u = g.u64_below(n as u64) as u32;
                let v = g.u64_below(n as u64) as u32;
                if u != v {
                    el.push(u, v, g.f64().max(1e-12));
                }
            }
        }
        el
    }

    fn all_specs(g: &mut crate::util::minitest::Gen, n: u32, p: u32) -> Vec<PartitionSpec> {
        let map: Vec<u32> = (0..n).map(|_| g.u64_below(p as u64) as u32).collect();
        vec![
            PartitionSpec::Block,
            PartitionSpec::DegreeBalanced,
            PartitionSpec::HubScatter { top_k: 0 },
            PartitionSpec::HubScatter { top_k: 1 + g.u64_below(16) as u32 },
            PartitionSpec::Explicit(Arc::new(map)),
            PartitionSpec::multilevel(),
            PartitionSpec::Multilevel { eps: 1.0 + g.f64() * 0.5, seed: g.u64() },
        ]
    }

    /// `owner` / `local_index` must form a bijection tiling `[0, n)` for
    /// every strategy, including n < p and the n = 0 degenerate.
    #[test]
    fn owner_local_index_bijection_all_strategies() {
        props("partition bijection", 120, |g| {
            // Mix of dense, sparse, n < p, and empty cases.
            let n = *g.choose(&[0u32, 1, 2, 3, 7, 40, 257]) + g.u64_below(40) as u32;
            let p = g.usize_in(1, 48) as u32;
            let el = random_graph(g, n);
            for spec in all_specs(g, n, p) {
                let part = Partition::build(&spec, &el, n, p).unwrap();
                assert_eq!(part.n_ranks(), p, "{}", spec.label());
                assert_eq!(part.n_vertices(), n, "{}", spec.label());
                // Per-rank sizes tile n.
                let total: u64 = (0..p).map(|r| part.n_local(r) as u64).sum();
                assert_eq!(total, n as u64, "{}: sizes must sum to n", spec.label());
                // owner/local_index and vertex_of are mutually inverse.
                let mut seen = vec![false; n as usize];
                for r in 0..p {
                    let vs = part.vertices_of(r);
                    assert_eq!(vs.len() as u32, part.n_local(r));
                    assert!(
                        vs.windows(2).all(|w| w[0] < w[1]),
                        "{}: rank rows must be ascending",
                        spec.label()
                    );
                    for (row, &v) in vs.iter().enumerate() {
                        assert!(v < n, "{}: vertex_of out of range", spec.label());
                        assert!(!seen[v as usize], "{}: vertex {v} owned twice", spec.label());
                        seen[v as usize] = true;
                        assert_eq!(part.owner(v), r, "{}", spec.label());
                        assert_eq!(part.local_index(v), row as u32, "{}", spec.label());
                        assert_eq!(part.vertex_of(r, row as u32), v, "{}", spec.label());
                    }
                }
                assert!(seen.iter().all(|&s| s), "{}: not all vertices covered", spec.label());
            }
        });
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(PartitionSpec::parse("block"), Some(PartitionSpec::Block));
        assert_eq!(PartitionSpec::parse("DEGREE"), Some(PartitionSpec::DegreeBalanced));
        assert_eq!(PartitionSpec::parse("hub"), Some(PartitionSpec::HubScatter { top_k: 0 }));
        assert_eq!(PartitionSpec::parse("metis"), None);
        assert_eq!(PartitionSpec::parse("multilevel"), Some(PartitionSpec::multilevel()));
        assert_eq!(
            PartitionSpec::parse("Multilevel:1.25"),
            Some(PartitionSpec::Multilevel { eps: 1.25, seed: multilevel::DEFAULT_SEED })
        );
        // ε below 1 would make the balance cap infeasible; reject it.
        assert_eq!(PartitionSpec::parse("multilevel:0.9"), None);
        assert_eq!(PartitionSpec::parse("multilevel:abc"), None);
        assert_eq!(PartitionSpec::parse("multilevel:"), None);
    }

    #[test]
    fn explicit_rejects_bad_maps() {
        let el = EdgeList::with_vertices(4);
        // Wrong length.
        let spec = PartitionSpec::Explicit(Arc::new(vec![0, 1]));
        assert!(Partition::build(&spec, &el, 4, 2).is_err());
        // Owner out of range.
        let spec = PartitionSpec::Explicit(Arc::new(vec![0, 1, 2, 0]));
        assert!(Partition::build(&spec, &el, 4, 2).is_err());
        // Valid scatter map.
        let spec = PartitionSpec::Explicit(Arc::new(vec![1, 0, 1, 0]));
        let part = Partition::build(&spec, &el, 4, 2).unwrap();
        assert_eq!(part.owner(0), 1);
        assert_eq!(part.local_index(2), 1, "vertex 2 is rank 1's second vertex");
        assert_eq!(part.vertices_of(0), vec![1, 3]);
    }
}
