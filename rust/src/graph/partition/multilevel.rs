//! Multilevel edge-cut-minimizing partitioning (coarsen / partition /
//! refine), the classic KaHIP/METIS recipe at reproduction scale.
//!
//! The vertex-balanced strategies (Block / DegreeBalanced / HubScatter)
//! all sit at the ~`1 − 1/p` random-cut floor on scrambled R-MAT inputs:
//! they place by id or degree, never by adjacency, so nearly every edge
//! crosses a rank boundary and becomes interconnect traffic. This module
//! is the cut lever:
//!
//! 1. **Coarsening** — repeated heavy-edge matching. Vertices are visited
//!    in a seeded random order; each unmatched vertex pairs with the
//!    unmatched neighbour behind the heaviest edge (ties: lowest id),
//!    subject to a combined-weight cap so coarse vertices stay small
//!    enough for the balance bound below. Matched pairs collapse, parallel
//!    coarse edges merge by weight summation, until the graph has at most
//!    [`COARSEN_PER_RANK`]`·p` vertices (or matching stalls).
//! 2. **Initial partition** — greedy balanced k-way assignment on the
//!    coarsest graph: vertices in descending-weight order each go to the
//!    rank with the strongest existing connection that still fits under
//!    the balance cap (ties: lightest load, then lowest rank id).
//! 3. **Uncoarsening + refinement** — the assignment is projected back
//!    level by level; at every level boundary KL/FM-style passes move a
//!    vertex to the neighbouring rank with the highest positive cut gain,
//!    never violating the cap, until a pass makes no move (or
//!    [`MAX_REFINE_PASSES`] is hit). Gains are strictly positive, so the
//!    cut is monotone non-increasing across passes — a property the
//!    `partition_props` test tier asserts from the [`MultilevelTrace`].
//!
//! **Balance bound.** With `ideal = ⌈n/p⌉`, `slack = ⌊(ε−1)·n/p⌋`
//! (clamped to `n` — a cap beyond every vertex is meaningless) and
//! `cap = ideal + slack`, every produced partition satisfies
//! `max_rank_vertices ≤ cap`: matching never builds a vertex heavier than
//! `max(1, slack)`, and greedy placement of items that small always finds
//! a rank under the cap (the least-loaded rank holds at most
//! `⌊(n−w)/p⌋` weight).
//!
//! **Block fallback.** After refinement the builder compares its edge cut
//! against the paper's block layout and keeps whichever is lower (block
//! wins ties only when strictly better). On graphs where multilevel cannot
//! help — complete graphs, `n ≤ p` confetti — the result is therefore
//! never worse than the baseline, which is what lets the conformance
//! matrix and the CI `partition-quality` gate assert
//! `cut(multilevel) ≤ cut(block)` unconditionally. The fallback is
//! recorded in [`MultilevelTrace::used_fallback`].
//!
//! **Determinism.** The only randomness is the matching visit order,
//! drawn from a [`Xoshiro256`] stream seeded by the spec (default
//! [`DEFAULT_SEED`]); everything else is integer arithmetic with
//! value-based tie-breaks, so the owner map is a pure function of
//! `(graph, p, ε, seed)` and `python/tools/pipeline_check.py` replays it
//! bit-for-bit.

use super::{BlockPartition, MappedData, MappedPartition};
use crate::graph::EdgeList;
use crate::util::prng::Xoshiro256;

/// Default balance factor ε: ranks may exceed the ideal vertex count by 5 %.
pub const DEFAULT_EPS: f64 = 1.05;

/// Default matching-order seed ("MLTV"). Fixed so partitions are stable
/// across runs; override through [`super::PartitionSpec::Multilevel`].
pub const DEFAULT_SEED: u64 = 0x4D4C_5456;

/// Coarsening stops once the graph has at most this many vertices per rank.
pub const COARSEN_PER_RANK: u32 = 32;

/// Refinement passes per level (each level also stops early on the first
/// pass that makes no move).
pub const MAX_REFINE_PASSES: usize = 8;

/// Introspection record of one level of the multilevel pipeline, in
/// refinement order (coarsest first, finest last).
#[derive(Debug, Clone)]
pub struct LevelTrace {
    /// Vertices at this level.
    pub n_vertices: u32,
    /// Per-vertex weights (fine vertices represented); sums to `n`.
    pub vertex_weights: Vec<u64>,
    /// Matching used to coarsen *away from* this level: `matching[v]` is
    /// the partner (or `v` itself when unmatched). Empty for the coarsest
    /// level, which was never coarsened further.
    pub matching: Vec<u32>,
    /// Pairs collapsed by that matching (0 for the coarsest level).
    pub matched_pairs: u32,
    /// Edge cut (in fine-edge units — coarse edge weights are collapse
    /// counts) before refinement at this level, then after each pass.
    pub pass_cuts: Vec<u64>,
}

/// Full trace of one multilevel build (property-test introspection).
#[derive(Debug, Clone)]
pub struct MultilevelTrace {
    /// Per-rank vertex-weight cap `⌈n/p⌉ + ⌊(ε−1)·n/p⌋`.
    pub cap: u64,
    /// Max combined weight a matching may build (`max(1, slack)`).
    pub wmax: u64,
    /// Levels in refinement order (coarsest first).
    pub levels: Vec<LevelTrace>,
    /// Cut of the refined multilevel assignment (before the fallback
    /// comparison).
    pub final_cut: u64,
    /// Cut of the paper's block layout on the same graph.
    pub block_cut: u64,
    /// Whether the block layout won the comparison and was returned.
    pub used_fallback: bool,
    /// Refinement passes executed, summed over every level (each level
    /// runs at most `MAX_REFINE_PASSES`, stopping early when a pass moves
    /// nothing).
    pub passes_run: u64,
    /// Positive-gain single-vertex moves applied across all passes.
    pub moves_applied: u64,
    /// Total cut weight removed by those moves (fine-edge units — the sum
    /// of every applied move's gain, so `initial cut - gain_total` is the
    /// refined cut when no level re-adds cut via uncoarsening).
    pub gain_total: u64,
}

/// Merged adjacency: one `(neighbour, weight)` entry per neighbour,
/// ascending id, parallel edges summed, self-loops dropped.
type Adjacency = Vec<Vec<(u32, u64)>>;

fn merge_rows(mut rows: Adjacency) -> Adjacency {
    for row in &mut rows {
        row.sort_unstable();
        let mut out = Vec::with_capacity(row.len());
        for &(u, w) in row.iter() {
            match out.last_mut() {
                Some(&mut (lu, ref mut lw)) if lu == u => *lw += w,
                _ => out.push((u, w)),
            }
        }
        *row = out;
    }
    rows
}

fn fine_adjacency(g: &EdgeList, n: u32) -> Adjacency {
    let mut rows: Adjacency = vec![Vec::new(); n as usize];
    for e in &g.edges {
        if e.u == e.v {
            continue;
        }
        rows[e.u as usize].push((e.v, 1));
        rows[e.v as usize].push((e.u, 1));
    }
    merge_rows(rows)
}

/// Total cut weight of `owner` over `adj` (each undirected entry pair
/// counted once).
fn cut_of(adj: &Adjacency, owner: &[u32]) -> u64 {
    let mut cut = 0u64;
    for (v, row) in adj.iter().enumerate() {
        for &(u, w) in row {
            if owner[u as usize] != owner[v] {
                cut += w;
            }
        }
    }
    cut / 2
}

/// One KL/FM-style boundary refinement phase at one level: repeated
/// positive-gain single-vertex moves under the balance cap. Returns the
/// cut after each pass (index 0 = before refinement) and accumulates the
/// work counters (`passes_run` / `moves_applied` / `gain_total`) into the
/// build trace.
fn refine(
    adj: &Adjacency,
    vwt: &[u64],
    owner: &mut [u32],
    loads: &mut [u64],
    cap: u64,
    trace: &mut MultilevelTrace,
) -> Vec<u64> {
    let p = loads.len();
    let mut conn = vec![0u64; p];
    let mut touched: Vec<u32> = Vec::new();
    let mut cut = cut_of(adj, owner);
    let mut pass_cuts = vec![cut];
    for _ in 0..MAX_REFINE_PASSES {
        trace.passes_run += 1;
        let mut moves = 0u32;
        for v in 0..adj.len() {
            let r = owner[v];
            for &(u, w) in &adj[v] {
                let o = owner[u as usize];
                if conn[o as usize] == 0 {
                    touched.push(o);
                }
                conn[o as usize] += w;
            }
            // Best strictly-positive-gain destination under the cap;
            // ties prefer the lighter then lower-id rank.
            let mut best: Option<(u64, u64, u32)> = None; // (gain, load, rank)
            for &s in &touched {
                if s == r || loads[s as usize] + vwt[v] > cap {
                    continue;
                }
                let (cs, cr) = (conn[s as usize], conn[r as usize]);
                if cs <= cr {
                    continue;
                }
                let cand = (cs - cr, loads[s as usize], s);
                let better = match best {
                    None => true,
                    Some((bg, bl, bs)) => {
                        cand.0 > bg || (cand.0 == bg && (cand.1, cand.2) < (bl, bs))
                    }
                };
                if better {
                    best = Some(cand);
                }
            }
            if let Some((gain, _, s)) = best {
                loads[r as usize] -= vwt[v];
                loads[s as usize] += vwt[v];
                owner[v] = s;
                cut -= gain;
                moves += 1;
                trace.moves_applied += 1;
                trace.gain_total += gain;
            }
            for &o in &touched {
                conn[o as usize] = 0;
            }
            touched.clear();
        }
        pass_cuts.push(cut);
        if moves == 0 {
            break;
        }
    }
    pass_cuts
}

/// Build the multilevel partition and its full trace.
pub fn multilevel_with_trace(
    g: &EdgeList,
    n: u32,
    p: u32,
    eps: f64,
    seed: u64,
) -> (MappedPartition, MultilevelTrace) {
    let mut trace = MultilevelTrace {
        cap: n as u64,
        wmax: 1,
        levels: Vec::new(),
        final_cut: 0,
        block_cut: 0,
        used_fallback: false,
        passes_run: 0,
        moves_applied: 0,
        gain_total: 0,
    };
    if n == 0 {
        return (MappedPartition::new(MappedData::from_owner_map(Vec::new(), p)), trace);
    }
    if p == 1 {
        let owner = vec![0u32; n as usize];
        return (MappedPartition::new(MappedData::from_owner_map(owner, p)), trace);
    }

    // Manual ceiling division (`div_ceil` needs Rust 1.73 > the 1.70 MSRV).
    let ideal = ((n as u64) + (p as u64) - 1) / p as u64;
    // Slack clamps at n: a cap beyond n is meaningless, and the clamp
    // keeps the f64->u64 cast in range for arbitrarily large ε values
    // (the CLI accepts any finite ε >= 1).
    let slack = ((eps - 1.0).max(0.0) * n as f64 / p as f64).floor().min(n as f64) as u64;
    let cap = ideal + slack;
    let wmax = slack.max(1);
    trace.cap = cap;
    trace.wmax = wmax;

    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut adj = fine_adjacency(g, n);
    let mut vwt: Vec<u64> = vec![1; n as usize];
    // Finer levels stacked during coarsening; `cid` maps each to the next
    // coarser level's ids.
    struct FinerLevel {
        adj: Adjacency,
        vwt: Vec<u64>,
        cid: Vec<u32>,
        matching: Vec<u32>,
        matched_pairs: u32,
    }
    let mut finer: Vec<FinerLevel> = Vec::new();
    let target = (COARSEN_PER_RANK as u64 * p as u64).min(u32::MAX as u64) as usize;

    // ---- 1. coarsening: seeded heavy-edge matching ----
    while adj.len() > target {
        let n_cur = adj.len();
        let mut order: Vec<u32> = (0..n_cur as u32).collect();
        rng.shuffle(&mut order);
        let mut matching: Vec<u32> = (0..n_cur as u32).collect();
        let mut matched_pairs = 0u32;
        for &v in &order {
            let v = v as usize;
            if matching[v] != v as u32 {
                continue;
            }
            // Heaviest connecting edge to an unmatched neighbour under the
            // weight cap; ties broken by lowest neighbour id.
            let mut best: Option<(u64, u32)> = None;
            for &(u, w) in &adj[v] {
                if u as usize == v || matching[u as usize] != u {
                    continue;
                }
                if vwt[v] + vwt[u as usize] > wmax {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bw, bu)) => w > bw || (w == bw && u < bu),
                };
                if better {
                    best = Some((w, u));
                }
            }
            if let Some((_, u)) = best {
                matching[v] = u;
                matching[u as usize] = v as u32;
                matched_pairs += 1;
            }
        }
        if matched_pairs == 0 {
            break;
        }
        // Coarse ids in ascending finest-member order.
        let mut cid = vec![u32::MAX; n_cur];
        let mut next = 0u32;
        for v in 0..n_cur {
            if cid[v] == u32::MAX {
                cid[v] = next;
                let m = matching[v] as usize;
                if m != v {
                    cid[m] = next;
                }
                next += 1;
            }
        }
        let mut c_vwt = vec![0u64; next as usize];
        for v in 0..n_cur {
            c_vwt[cid[v] as usize] += vwt[v];
        }
        let mut c_rows: Adjacency = vec![Vec::new(); next as usize];
        for v in 0..n_cur {
            let cv = cid[v];
            for &(u, w) in &adj[v] {
                let cu = cid[u as usize];
                if cu != cv {
                    c_rows[cv as usize].push((cu, w));
                }
            }
        }
        let c_adj = merge_rows(c_rows);
        finer.push(FinerLevel {
            adj: std::mem::replace(&mut adj, c_adj),
            vwt: std::mem::replace(&mut vwt, c_vwt),
            cid,
            matching,
            matched_pairs,
        });
    }

    // ---- 2. greedy balanced k-way assignment on the coarsest graph ----
    let n_cur = adj.len();
    let mut loads = vec![0u64; p as usize];
    let mut owner = vec![u32::MAX; n_cur];
    let mut order: Vec<u32> = (0..n_cur as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(vwt[v as usize]), v));
    let mut conn = vec![0u64; p as usize];
    let mut touched: Vec<u32> = Vec::new();
    for &v in &order {
        let v = v as usize;
        for &(u, w) in &adj[v] {
            let o = owner[u as usize];
            if o != u32::MAX {
                if conn[o as usize] == 0 {
                    touched.push(o);
                }
                conn[o as usize] += w;
            }
        }
        // Strongest connection that fits under the cap; ties prefer the
        // lighter then lower-id rank (ranks with no connection compete
        // with conn = 0).
        let mut best: Option<(u64, u64, u32)> = None; // (conn, load, rank)
        for r in 0..p {
            if loads[r as usize] + vwt[v] > cap {
                continue;
            }
            let cand = (conn[r as usize], loads[r as usize], r);
            let better = match best {
                None => true,
                Some((bc, bl, br)) => {
                    cand.0 > bc || (cand.0 == bc && (cand.1, cand.2) < (bl, br))
                }
            };
            if better {
                best = Some(cand);
            }
        }
        // Unreachable by the cap/wmax construction (see module docs), kept
        // as a safe fallback rather than a panic path.
        let r = best.map(|(_, _, r)| r).unwrap_or_else(|| {
            (0..p).min_by_key(|&r| (loads[r as usize], r)).expect("p >= 1")
        });
        owner[v] = r;
        loads[r as usize] += vwt[v];
        for &o in &touched {
            conn[o as usize] = 0;
        }
        touched.clear();
    }

    // ---- 3. refine, then uncoarsen level by level and refine again ----
    let pass_cuts = refine(&adj, &vwt, &mut owner, &mut loads, cap, &mut trace);
    trace.levels.push(LevelTrace {
        n_vertices: n_cur as u32,
        vertex_weights: vwt.clone(),
        matching: Vec::new(),
        matched_pairs: 0,
        pass_cuts,
    });
    for lvl in finer.into_iter().rev() {
        let mut f_owner: Vec<u32> =
            (0..lvl.vwt.len()).map(|v| owner[lvl.cid[v] as usize]).collect();
        let mut f_loads = vec![0u64; p as usize];
        for (v, &o) in f_owner.iter().enumerate() {
            f_loads[o as usize] += lvl.vwt[v];
        }
        let pass_cuts = refine(&lvl.adj, &lvl.vwt, &mut f_owner, &mut f_loads, cap, &mut trace);
        trace.levels.push(LevelTrace {
            n_vertices: lvl.vwt.len() as u32,
            vertex_weights: lvl.vwt,
            matching: lvl.matching,
            matched_pairs: lvl.matched_pairs,
            pass_cuts,
        });
        owner = f_owner;
    }
    let final_cut = {
        let finest = trace.levels.last().expect("at least one level");
        *finest.pass_cuts.last().expect("refine records the initial cut")
    };
    trace.final_cut = final_cut;

    // ---- 4. never-worse-than-block fallback ----
    let block = BlockPartition::new(n, p);
    let mut block_cut = 0u64;
    for e in &g.edges {
        if e.u != e.v && block.owner(e.u) != block.owner(e.v) {
            block_cut += 1;
        }
    }
    trace.block_cut = block_cut;
    if trace.final_cut > block_cut {
        trace.used_fallback = true;
        let owner: Vec<u32> = (0..n).map(|v| block.owner(v)).collect();
        return (MappedPartition::new(MappedData::from_owner_map(owner, p)), trace);
    }
    (MappedPartition::new(MappedData::from_owner_map(owner, p)), trace)
}

/// Build without the trace (the [`super::Partition::build`] entry point).
pub(super) fn multilevel(g: &EdgeList, n: u32, p: u32, eps: f64, seed: u64) -> MappedPartition {
    multilevel_with_trace(g, n, p, eps, seed).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::Partition;

    fn cut_under(g: &EdgeList, part: &Partition) -> u64 {
        g.edges.iter().filter(|e| part.owner(e.u) != part.owner(e.v)).count() as u64
    }

    fn build(g: &EdgeList, n: u32, p: u32) -> (Partition, MultilevelTrace) {
        let (mapped, trace) = multilevel_with_trace(g, n, p, DEFAULT_EPS, DEFAULT_SEED);
        (Partition::Mapped(mapped), trace)
    }

    #[test]
    fn degenerate_shapes() {
        // n = 0: empty owner map over p ranks.
        let (part, _) = build(&EdgeList::with_vertices(0), 0, 4);
        assert_eq!(part.n_vertices(), 0);
        assert_eq!((0..4).map(|r| part.n_local(r)).sum::<u32>(), 0);
        // p = 1: everything on rank 0.
        let mut g = EdgeList::with_vertices(5);
        g.push(0, 1, 0.5);
        let (part, _) = build(&g, 5, 1);
        assert_eq!(part.n_local(0), 5);
        // n < p: unit weights, each rank holds at most cap = 1 vertex.
        let (part, trace) = build(&g, 5, 9);
        assert_eq!((0..9).map(|r| part.n_local(r)).sum::<u32>(), 5);
        assert!((0..9).all(|r| part.n_local(r) as u64 <= trace.cap));
    }

    /// The dramatic locality case: a path whose vertex ids are scrambled.
    /// Block cuts ~3/4 of all edges; multilevel coarsening follows the
    /// edges and recovers near-contiguous segments. (Python port pins the
    /// exact values: multilevel 28 vs block 3056 cut edges.)
    #[test]
    fn scrambled_path_is_a_blowout() {
        let n = 4096u32;
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(5);
        let mut perm: Vec<u32> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut g = EdgeList::with_vertices(n);
        for i in 0..(n - 1) as usize {
            g.push(perm[i], perm[i + 1], 0.5);
        }
        let (part, trace) = build(&g, n, 4);
        let ml = cut_under(&g, &part);
        let block = cut_under(&g, &Partition::block(n, 4));
        assert!(!trace.used_fallback);
        assert!(block > 2000, "scrambled ids leave block near the random floor: {block}");
        assert!(ml < 100, "multilevel must recover path locality: cut {ml}");
    }

    /// Extreme ε values (the CLI accepts any finite ε >= 1) must clamp
    /// instead of overflowing the slack cast, and still tile [0, n).
    #[test]
    fn huge_eps_clamps_instead_of_overflowing() {
        let mut g = EdgeList::with_vertices(64);
        for i in 0..63 {
            g.push(i, i + 1, 0.5);
        }
        let (mapped, trace) = multilevel_with_trace(&g, 64, 4, 1e19, DEFAULT_SEED);
        assert_eq!(trace.cap, 16 + 64, "slack clamps at n");
        let part = Partition::Mapped(mapped);
        assert_eq!((0..4).map(|r| part.n_local(r)).sum::<u32>(), 64);
    }

    /// The refinement-work counters must account exactly: `passes_run`
    /// matches the recorded pass cuts and `gain_total` is the total cut
    /// weight the passes removed.
    #[test]
    fn refinement_counters_account_for_the_work() {
        let n = 4096u32;
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(5);
        let mut perm: Vec<u32> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut g = EdgeList::with_vertices(n);
        for i in 0..(n - 1) as usize {
            g.push(perm[i], perm[i + 1], 0.5);
        }
        let (_, trace) = build(&g, n, 4);
        let passes: u64 = trace.levels.iter().map(|l| l.pass_cuts.len() as u64 - 1).sum();
        assert_eq!(trace.passes_run, passes, "one pass per recorded pass cut");
        let gain: u64 = trace
            .levels
            .iter()
            .map(|l| l.pass_cuts[0] - *l.pass_cuts.last().expect("never empty"))
            .sum();
        assert_eq!(trace.gain_total, gain, "gain sums to the cut removed per level");
        assert!(trace.moves_applied > 0 && trace.gain_total > 0, "refinement did work");
    }

    /// On a contiguous path, block is already optimal (p - 1 cut edges);
    /// the fallback guarantees multilevel never does worse.
    #[test]
    fn contiguous_path_never_worse_than_block() {
        let n = 4096u32;
        let mut g = EdgeList::with_vertices(n);
        for i in 0..n - 1 {
            g.push(i, i + 1, 0.5);
        }
        let (part, _) = build(&g, n, 4);
        assert!(cut_under(&g, &part) <= 3, "block's optimal 3-edge cut is the ceiling");
    }

    /// Trace smoke on a generated fixture: weights conserved, matchings
    /// are involutions under the weight cap, cuts monotone per level.
    /// (The full sweep lives in tests/partition_props.rs.)
    #[test]
    fn trace_invariants_on_rmat() {
        use crate::graph::generators::{generate, GraphFamily};
        use crate::graph::preprocess::preprocess;
        let (g, _) = preprocess(&generate(GraphFamily::Rmat, 9, 31));
        let n = g.n_vertices;
        // 8 ranks: the 32·p coarsening target (256) is below n = 512, so
        // at least one heavy-edge-matching level must be built.
        let (part, trace) = build(&g, n, 8);
        assert!(trace.levels.len() >= 2, "scale-9 at 8 ranks must coarsen at least once");
        for lvl in &trace.levels {
            assert_eq!(lvl.vertex_weights.iter().sum::<u64>(), n as u64);
            for w in lvl.pass_cuts.windows(2) {
                assert!(w[1] <= w[0]);
            }
            for (v, &m) in lvl.matching.iter().enumerate() {
                assert_eq!(lvl.matching[m as usize], v as u32, "matching is an involution");
                if m as usize != v {
                    assert!(
                        lvl.vertex_weights[v] + lvl.vertex_weights[m as usize] <= trace.wmax
                    );
                }
            }
        }
        assert_eq!(
            cut_under(&g, &part),
            trace.final_cut.min(trace.block_cut),
            "returned partition's cut must match the trace accounting"
        );
    }
}
