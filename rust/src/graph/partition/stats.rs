//! Partition quality metrics.
//!
//! Computed once per run (initialization time, like the paper's hash-table
//! build) and folded into [`GhsRun`](crate::ghs::result::GhsRun) so the
//! sim's communication costs can be correlated with cut quality. Metric
//! definitions are documented in the README ("Choosing a partition").

use super::Partition;
use crate::graph::EdgeList;

/// Quality report of one partition over one concrete graph.
///
/// * *vertex balance*: `max_rank_vertices / (n/p)` — 1.0 is perfect.
/// * *edge balance*: `max_rank_edges / (2m/p)` where per-rank edge load is
///   counted in adjacency entries exactly as the CSR stores them (a local
///   edge is 2 entries on one rank, a cut edge 1 entry on each side).
/// * *remote-edge fraction* (relative edge cut): share of edges whose
///   endpoints live on different ranks — every such edge turns Test /
///   Accept / Reject / Report traffic into interconnect messages.
/// * *max owner degree*: the adjacency load of the rank owning the
///   heaviest single vertex — the hub hotspot block partitioning suffers
///   from on R-MAT inputs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PartitionStats {
    /// Partitioned vertices.
    pub n_vertices: u32,
    /// Ranks.
    pub n_ranks: u32,
    /// Undirected edges in the graph.
    pub n_edges: u64,
    /// Vertices on the most loaded rank.
    pub max_rank_vertices: u32,
    /// Vertices on the least loaded rank.
    pub min_rank_vertices: u32,
    /// `max_rank_vertices / (n/p)`.
    pub vertex_imbalance: f64,
    /// Adjacency entries on the most loaded rank.
    pub max_rank_edges: u64,
    /// `max_rank_edges / (2m/p)`.
    pub edge_imbalance: f64,
    /// Edges with endpoints on two different ranks.
    pub cut_edges: u64,
    /// `cut_edges / m`.
    pub remote_edge_fraction: f64,
    /// Degree of the single highest-degree vertex.
    pub max_vertex_degree: u64,
    /// Adjacency entries on the rank owning that vertex.
    pub max_owner_degree: u64,
}

impl PartitionStats {
    /// Compute the report for `part` over `g`. O(n + m).
    pub fn compute(g: &EdgeList, part: &Partition) -> Self {
        let n = part.n_vertices();
        let p = part.n_ranks();
        let m = g.n_edges() as u64;
        let mut vload: Vec<u32> = (0..p).map(|r| part.n_local(r)).collect();
        if vload.is_empty() {
            vload.push(0);
        }
        let mut eload = vec![0u64; p as usize];
        let mut deg = vec![0u64; n as usize];
        let mut cut = 0u64;
        for e in &g.edges {
            let (ru, rv) = (part.owner(e.u), part.owner(e.v));
            eload[ru as usize] += 1;
            eload[rv as usize] += 1;
            if ru != rv {
                cut += 1;
            }
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let max_rank_vertices = *vload.iter().max().unwrap();
        let min_rank_vertices = *vload.iter().min().unwrap();
        let max_rank_edges = eload.iter().copied().max().unwrap_or(0);
        let (max_vertex_degree, hub) = deg
            .iter()
            .enumerate()
            .map(|(v, &d)| (d, v as u32))
            .max()
            .unwrap_or((0, 0));
        let max_owner_degree = if n > 0 { eload[part.owner(hub) as usize] } else { 0 };
        let ratio = |max: f64, ideal: f64| if ideal > 0.0 { max / ideal } else { 0.0 };
        Self {
            n_vertices: n,
            n_ranks: p,
            n_edges: m,
            max_rank_vertices,
            min_rank_vertices,
            vertex_imbalance: ratio(max_rank_vertices as f64, n as f64 / p as f64),
            max_rank_edges,
            edge_imbalance: ratio(max_rank_edges as f64, 2.0 * m as f64 / p as f64),
            cut_edges: cut,
            remote_edge_fraction: if m > 0 { cut as f64 / m as f64 } else { 0.0 },
            max_vertex_degree,
            max_owner_degree,
        }
    }

    /// The absolute edge cut (edges whose endpoints live on different
    /// ranks) — the quantity the multilevel strategy minimizes and the
    /// conformance/CI quality gates compare across strategies.
    pub fn edge_cut(&self) -> u64 {
        self.cut_edges
    }

    /// One-line human summary (used by the `run` CLI output).
    pub fn summary(&self) -> String {
        format!(
            "vtx balance {:.2}x, edge balance {:.2}x, remote edges {:.1}%",
            self.vertex_imbalance,
            self.edge_imbalance,
            100.0 * self.remote_edge_fraction
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::PartitionSpec;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::graph::preprocess::preprocess;

    #[test]
    fn path_graph_two_ranks() {
        // 0-1-2-3 split {0,1} | {2,3}: one cut edge of three.
        let mut g = EdgeList::with_vertices(4);
        g.push(0, 1, 0.1);
        g.push(1, 2, 0.2);
        g.push(2, 3, 0.3);
        let part = Partition::block(4, 2);
        let s = PartitionStats::compute(&g, &part);
        assert_eq!(s.cut_edges, 1);
        assert!((s.remote_edge_fraction - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.max_rank_vertices, 2);
        assert_eq!(s.min_rank_vertices, 2);
        assert!((s.vertex_imbalance - 1.0).abs() < 1e-12);
        // Rank 0 stores entries for (0,1)x2 + (1,2); rank 1 for (2,3)x2 + (1,2).
        assert_eq!(s.max_rank_edges, 3);
        assert_eq!(s.max_vertex_degree, 2);
    }

    #[test]
    fn empty_graph_is_all_zeros() {
        let g = EdgeList::with_vertices(0);
        let part = Partition::block(0, 4);
        let s = PartitionStats::compute(&g, &part);
        assert_eq!(s.cut_edges, 0);
        assert_eq!(s.remote_edge_fraction, 0.0);
        assert_eq!(s.max_rank_edges, 0);
        assert_eq!(s.vertex_imbalance, 0.0);
    }

    #[test]
    fn hub_scatter_improves_rmat_skew_metrics() {
        // The acceptance claim behind results/partition_baseline.md, at a
        // test-sized scale: on RMAT skew, hub-scatter reduces the max-rank
        // edge load vs block, and the star hotspot is visible to block.
        let (g, _) = preprocess(&generate(GraphFamily::Rmat, 9, 31));
        let n = g.n_vertices;
        let block = PartitionStats::compute(&g, &Partition::block(n, 16));
        let hub = PartitionStats::compute(
            &g,
            &Partition::build(&PartitionSpec::HubScatter { top_k: 0 }, &g, n, 16).unwrap(),
        );
        let degree = PartitionStats::compute(
            &g,
            &Partition::build(&PartitionSpec::DegreeBalanced, &g, n, 16).unwrap(),
        );
        assert!(
            hub.max_rank_edges < block.max_rank_edges,
            "hub-scatter must reduce max edge load: {} vs block {}",
            hub.max_rank_edges,
            block.max_rank_edges
        );
        assert!(
            degree.max_rank_edges <= block.max_rank_edges,
            "degree-balanced must not exceed block's max edge load"
        );
    }

    #[test]
    fn multilevel_cuts_below_the_vertex_balanced_floor() {
        // Every vertex-balanced strategy sits near the 1 - 1/p random-cut
        // floor on scrambled RMAT; the multilevel strategy is the cut
        // lever and must land strictly below block (the builder's block
        // fallback makes `<=` structural; strictness is the quality
        // claim, pinned at full scale by tests/partition_props.rs).
        let (g, _) = preprocess(&generate(GraphFamily::Rmat, 9, 31));
        let n = g.n_vertices;
        let block = PartitionStats::compute(&g, &Partition::block(n, 16));
        let ml = PartitionStats::compute(
            &g,
            &Partition::build(&PartitionSpec::multilevel(), &g, n, 16).unwrap(),
        );
        assert!(
            ml.edge_cut() < block.edge_cut(),
            "multilevel must beat block's cut on RMAT skew: {} vs {}",
            ml.edge_cut(),
            block.edge_cut()
        );
        // The ε = 1.05 balance bound holds (same slack arithmetic as the
        // builder, so the comparison is exact).
        let eps = crate::graph::partition::multilevel::DEFAULT_EPS;
        let cap = (n as u64 + 15) / 16 + (((eps - 1.0) * n as f64 / 16.0).floor() as u64);
        assert!(
            ml.max_rank_vertices as u64 <= cap,
            "balance bound violated: {} > cap {cap}",
            ml.max_rank_vertices
        );
    }

    #[test]
    fn star_graph_hub_metrics() {
        // Star: vertex 0 has degree n-1; block gives rank 0 the entire hub.
        let mut g = EdgeList::with_vertices(8);
        for v in 1..8 {
            g.push(0, v, v as f64 / 16.0);
        }
        let s = PartitionStats::compute(&g, &Partition::block(8, 4));
        assert_eq!(s.max_vertex_degree, 7);
        assert_eq!(s.max_owner_degree, s.max_rank_edges, "hub owner is the heaviest rank");
        // 2 of rank 0's vertices: 0 (hub) and 1. Cut edges: all spokes to 2..8.
        assert_eq!(s.cut_edges, 6);
    }
}
