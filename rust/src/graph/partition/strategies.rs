//! Partition construction strategies (see the module docs of
//! [`super`] for the catalogue).
//!
//! All strategies are deterministic in the input graph: re-running a
//! workload reproduces the identical assignment, which the conformance
//! matrix and the `results/` snapshots rely on.

use std::cmp::Reverse;

use anyhow::{bail, Result};

use super::{BlockPartition, ContiguousPartition, MappedData, MappedPartition};
use crate::graph::{EdgeList, VertexId};

/// Per-vertex degrees over the first `n_vertices` ids (endpoints of every
/// stored undirected edge; a local edge contributes 2 to its rank's
/// adjacency load, exactly like the CSR stores it).
pub(super) fn degrees(g: &EdgeList, n_vertices: u32) -> Vec<u32> {
    let mut deg = vec![0u32; n_vertices as usize];
    for e in &g.edges {
        deg[e.u as usize] += 1;
        deg[e.v as usize] += 1;
    }
    deg
}

/// Contiguous chunking with boundaries chosen so per-rank adjacency-entry
/// counts are balanced: boundary `r` is placed where the cumulative degree
/// first reaches `r/p` of the total. Falls back to block boundaries on
/// edgeless graphs.
pub(super) fn degree_balanced(g: &EdgeList, n: u32, p: u32) -> ContiguousPartition {
    let deg = degrees(g, n);
    let total: u64 = deg.iter().map(|&d| d as u64).sum();
    let mut bounds = Vec::with_capacity(p as usize + 1);
    bounds.push(0u32);
    if total == 0 {
        let bp = BlockPartition::new(n, p);
        for r in 1..p {
            bounds.push(bp.first_vertex(r));
        }
    } else {
        let mut cum = 0u64;
        let mut v = 0u32;
        for r in 1..p {
            let target = (total as u128 * r as u128 / p as u128) as u64;
            while v < n && cum < target {
                cum += deg[v as usize] as u64;
                v += 1;
            }
            bounds.push(v);
        }
    }
    bounds.push(n);
    ContiguousPartition::new(bounds)
}

/// Skew-aware assignment: the `k` highest-degree vertices ("hubs") are
/// spread round-robin across ranks in serpentine (snake-draft) order, the
/// remaining vertices are block-filled in ascending id order. Per-rank
/// totals match the block partition's sizes, so vertex balance is
/// preserved while hub adjacency load is scattered. The serpentine
/// reversal on odd passes matters: a strict `i % p` in descending-degree
/// order would hand rank 0 the heaviest hub of *every* pass, recreating
/// the hotspot the strategy exists to break.
pub(super) fn hub_scatter(g: &EdgeList, n: u32, p: u32, top_k: u32) -> MappedPartition {
    let deg = degrees(g, n);
    let k = if top_k == 0 { 4u32.saturating_mul(p).min(n) } else { top_k.min(n) };
    // Hubs in descending degree, ties broken by ascending id (determinism).
    let mut by_deg: Vec<VertexId> = (0..n).collect();
    by_deg.sort_by_key(|&v| (Reverse(deg[v as usize]), v));
    let mut owner = vec![u32::MAX; n as usize];
    let mut hub_counts = vec![0u32; p as usize];
    for (i, &h) in by_deg[..k as usize].iter().enumerate() {
        let (pass, pos) = (i as u32 / p, i as u32 % p);
        let r = if pass % 2 == 0 { pos } else { p - 1 - pos };
        owner[h as usize] = r;
        hub_counts[r as usize] += 1;
    }
    // Remaining per-rank quotas mirror the block sizes. A rank may already
    // hold more hubs than its block size (k close to n); trim the excess
    // from the other ranks round-robin so quotas still sum to n - k.
    let bp = BlockPartition::new(n, p);
    let mut quota: Vec<u32> = (0..p).map(|r| bp.block_size(r)).collect();
    let mut excess = 0u64;
    for r in 0..p as usize {
        if hub_counts[r] > quota[r] {
            excess += (hub_counts[r] - quota[r]) as u64;
            quota[r] = 0;
        } else {
            quota[r] -= hub_counts[r];
        }
    }
    let mut r = 0usize;
    while excess > 0 {
        // Terminates: sum(quota) = (n - k) + excess >= excess > 0.
        if quota[r] > 0 {
            quota[r] -= 1;
            excess -= 1;
        }
        r = (r + 1) % p as usize;
    }
    // Block-fill the non-hub vertices into the quotas in ascending order.
    let mut cursor = 0usize;
    for v in 0..n {
        if owner[v as usize] != u32::MAX {
            continue;
        }
        while quota[cursor] == 0 {
            cursor += 1;
        }
        owner[v as usize] = cursor as u32;
        quota[cursor] -= 1;
    }
    MappedPartition::new(MappedData::from_owner_map(owner, p))
}

/// An explicit owner map (replayable experiments; see
/// [`crate::graph::io::read_owner_map`]).
pub(super) fn explicit(map: &[u32], n: u32, p: u32) -> Result<MappedPartition> {
    if map.len() != n as usize {
        bail!("owner map has {} entries but the graph has {n} vertices", map.len());
    }
    if let Some((v, &r)) = map.iter().enumerate().find(|&(_, &r)| r >= p) {
        bail!("owner map assigns vertex {v} to rank {r}, but only {p} ranks exist");
    }
    Ok(MappedPartition::new(MappedData::from_owner_map(map.to_vec(), p)))
}

#[cfg(test)]
mod tests {
    use super::super::{Partition, PartitionSpec};
    use super::*;
    use crate::graph::generators::{generate, GraphFamily};
    use crate::graph::preprocess::preprocess;

    /// Max per-rank adjacency entries under a partition.
    fn max_edge_load(g: &EdgeList, part: &Partition) -> u64 {
        let mut load = vec![0u64; part.n_ranks() as usize];
        for e in &g.edges {
            load[part.owner(e.u) as usize] += 1;
            load[part.owner(e.v) as usize] += 1;
        }
        load.into_iter().max().unwrap_or(0)
    }

    #[test]
    fn degree_balanced_is_contiguous_and_balances_edges() {
        let (g, _) = preprocess(&generate(GraphFamily::Rmat, 9, 7));
        let p = 8u32;
        let part = Partition::build(&PartitionSpec::DegreeBalanced, &g, g.n_vertices, p).unwrap();
        // Contiguous: each rank's vertices are an id interval.
        for r in 0..p {
            let vs = part.vertices_of(r);
            if let (Some(&first), Some(&last)) = (vs.first(), vs.last()) {
                assert_eq!(last - first + 1, vs.len() as u32, "rank {r} not contiguous");
            }
        }
        // Edge load no worse than block (RMAT skew makes block lopsided).
        let block = Partition::block(g.n_vertices, p);
        assert!(
            max_edge_load(&g, &part) <= max_edge_load(&g, &block),
            "degree-balanced must not exceed block's max edge load on RMAT"
        );
    }

    #[test]
    fn hub_scatter_separates_top_hubs() {
        let (g, _) = preprocess(&generate(GraphFamily::Rmat, 9, 7));
        let p = 8u32;
        let part = Partition::build(
            &PartitionSpec::HubScatter { top_k: p },
            &g,
            g.n_vertices,
            p,
        )
        .unwrap();
        // The p highest-degree vertices land on p distinct ranks.
        let deg = degrees(&g, g.n_vertices);
        let mut by_deg: Vec<u32> = (0..g.n_vertices).collect();
        by_deg.sort_by_key(|&v| (std::cmp::Reverse(deg[v as usize]), v));
        let owners: std::collections::HashSet<u32> =
            by_deg[..p as usize].iter().map(|&v| part.owner(v)).collect();
        assert_eq!(owners.len(), p as usize, "top-{p} hubs must hit {p} distinct ranks");
        // Vertex balance matches the block layout.
        let bp = BlockPartition::new(g.n_vertices, p);
        for r in 0..p {
            assert_eq!(part.n_local(r), bp.block_size(r), "rank {r} vertex count");
        }
    }

    #[test]
    fn hub_scatter_handles_k_near_n() {
        // k > n/p forces the quota-trimming path.
        let mut g = EdgeList::with_vertices(10);
        for v in 1..10 {
            g.push(0, v, v as f64 / 16.0);
        }
        let part = Partition::build(
            &PartitionSpec::HubScatter { top_k: 10 },
            &g,
            10,
            3,
        )
        .unwrap();
        let total: u32 = (0..3).map(|r| part.n_local(r)).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn degree_balanced_edgeless_falls_back_to_block() {
        let g = EdgeList::with_vertices(10);
        let part = Partition::build(&PartitionSpec::DegreeBalanced, &g, 10, 3).unwrap();
        let block = Partition::block(10, 3);
        for r in 0..3 {
            assert_eq!(part.n_local(r), block.n_local(r));
            assert_eq!(part.first_vertex(r), block.first_vertex(r));
        }
    }
}
