//! Block partitioning of vertices over ranks (paper §3: "All graph vertices
//! are sequentially distributed in blocks among the processes").

use crate::graph::VertexId;

/// Block distribution of `n_vertices` over `n_ranks`: the first
/// `n % p` ranks get `ceil(n/p)` vertices, the rest `floor(n/p)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockPartition {
    n_vertices: u32,
    n_ranks: u32,
}

impl BlockPartition {
    /// Create a partition; `n_ranks >= 1`.
    pub fn new(n_vertices: u32, n_ranks: u32) -> Self {
        assert!(n_ranks >= 1, "need at least one rank");
        Self { n_vertices, n_ranks }
    }

    /// Number of ranks.
    pub fn n_ranks(&self) -> u32 {
        self.n_ranks
    }

    /// Total vertices.
    pub fn n_vertices(&self) -> u32 {
        self.n_vertices
    }

    /// First vertex owned by `rank`.
    pub fn first_vertex(&self, rank: u32) -> VertexId {
        debug_assert!(rank < self.n_ranks);
        let n = self.n_vertices as u64;
        let p = self.n_ranks as u64;
        let r = rank as u64;
        let base = n / p;
        let extra = n % p;
        (r * base + r.min(extra)) as u32
    }

    /// Number of vertices owned by `rank`.
    pub fn block_size(&self, rank: u32) -> u32 {
        let n = self.n_vertices as u64;
        let p = self.n_ranks as u64;
        let base = (n / p) as u32;
        if (rank as u64) < n % p {
            base + 1
        } else {
            base
        }
    }

    /// Which rank owns vertex `v`?
    pub fn owner(&self, v: VertexId) -> u32 {
        debug_assert!(v < self.n_vertices);
        let n = self.n_vertices as u64;
        let p = self.n_ranks as u64;
        let base = n / p;
        let extra = n % p;
        let v = v as u64;
        let boundary = extra * (base + 1);
        if v < boundary {
            (v / (base + 1)) as u32
        } else {
            (extra + (v - boundary) / base.max(1)) as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::props;

    #[test]
    fn even_split() {
        let p = BlockPartition::new(100, 4);
        for r in 0..4 {
            assert_eq!(p.block_size(r), 25);
            assert_eq!(p.first_vertex(r), r * 25);
        }
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(24), 0);
        assert_eq!(p.owner(25), 1);
        assert_eq!(p.owner(99), 3);
    }

    #[test]
    fn uneven_split() {
        let p = BlockPartition::new(10, 3); // sizes 4, 3, 3
        assert_eq!(p.block_size(0), 4);
        assert_eq!(p.block_size(1), 3);
        assert_eq!(p.block_size(2), 3);
        assert_eq!(p.first_vertex(0), 0);
        assert_eq!(p.first_vertex(1), 4);
        assert_eq!(p.first_vertex(2), 7);
    }

    #[test]
    fn more_ranks_than_vertices() {
        let p = BlockPartition::new(3, 8);
        let total: u32 = (0..8).map(|r| p.block_size(r)).sum();
        assert_eq!(total, 3);
        for v in 0..3 {
            let r = p.owner(v);
            assert!(v >= p.first_vertex(r));
            assert!(v < p.first_vertex(r) + p.block_size(r));
        }
    }

    #[test]
    fn owner_and_blocks_agree() {
        props("partition owner/block agreement", 200, |g| {
            let n = g.usize_in(1, 10_000) as u32;
            let p_ranks = g.usize_in(1, 64) as u32;
            let p = BlockPartition::new(n, p_ranks);
            // Blocks tile [0, n).
            let mut covered = 0u32;
            for r in 0..p_ranks {
                assert_eq!(p.first_vertex(r), covered);
                covered += p.block_size(r);
            }
            assert_eq!(covered, n);
            // Spot-check owner() consistency on random vertices.
            for _ in 0..20 {
                if n == 0 {
                    break;
                }
                let v = g.u64_below(n as u64) as u32;
                let r = p.owner(v);
                assert!(v >= p.first_vertex(r) && v < p.first_vertex(r) + p.block_size(r));
            }
        });
    }
}
