//! Graph preprocessing (paper §3.1): remove self-loops and multi-edges
//! before the MST search. "The removal of multiple edges is used to fulfill
//! GHS algorithm condition which says that all the edges must be unique."
//!
//! For multi-edges we keep the minimum-weight copy — dropping heavier
//! parallel edges never changes the MST.

use std::collections::HashMap;

use crate::graph::{EdgeList, WeightedEdge};

/// Statistics from a preprocessing pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PreprocessStats {
    pub self_loops_removed: usize,
    pub multi_edges_removed: usize,
    pub edges_kept: usize,
}

/// Remove self-loops and parallel edges (keeping the lightest copy of each
/// parallel group). Returns the cleaned graph and statistics.
pub fn preprocess(g: &EdgeList) -> (EdgeList, PreprocessStats) {
    let mut stats = PreprocessStats::default();
    let mut best: HashMap<(u32, u32), WeightedEdge> = HashMap::with_capacity(g.n_edges());
    for e in &g.edges {
        if e.u == e.v {
            stats.self_loops_removed += 1;
            continue;
        }
        let key = e.canonical();
        match best.get_mut(&key) {
            None => {
                best.insert(key, *e);
            }
            Some(prev) => {
                stats.multi_edges_removed += 1;
                // Keep the lighter copy, tie-broken consistently by the
                // unique extended weight.
                if e.unique_weight() < prev.unique_weight() {
                    *prev = *e;
                }
            }
        }
    }
    let mut out = EdgeList::with_vertices(g.n_vertices);
    out.edges = best.into_values().collect();
    // Deterministic output order regardless of hash-map iteration.
    out.edges.sort_unstable_by(|a, b| a.canonical().cmp(&b.canonical()));
    stats.edges_kept = out.n_edges();
    (out, stats)
}

/// Check that no two edges share the same canonical endpoint pair and no
/// self-loops remain (the GHS precondition after preprocessing).
pub fn is_simple(g: &EdgeList) -> bool {
    let mut seen = std::collections::HashSet::with_capacity(g.n_edges());
    for e in &g.edges {
        if e.u == e.v || !seen.insert(e.canonical()) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::props;

    #[test]
    fn removes_self_loops() {
        let mut g = EdgeList::with_vertices(3);
        g.push(0, 0, 0.5);
        g.push(0, 1, 0.2);
        let (clean, stats) = preprocess(&g);
        assert_eq!(stats.self_loops_removed, 1);
        assert_eq!(clean.n_edges(), 1);
    }

    #[test]
    fn keeps_lightest_parallel_edge() {
        let mut g = EdgeList::with_vertices(2);
        g.push(0, 1, 0.9);
        g.push(1, 0, 0.3); // reversed orientation, still parallel
        g.push(0, 1, 0.7);
        let (clean, stats) = preprocess(&g);
        assert_eq!(stats.multi_edges_removed, 2);
        assert_eq!(clean.n_edges(), 1);
        assert_eq!(clean.edges[0].w, 0.3);
    }

    #[test]
    fn idempotent_and_simple() {
        props("preprocess idempotent", 100, |g| {
            let n = g.usize_in(2, 50) as u32;
            let mut el = EdgeList::with_vertices(n);
            for _ in 0..g.usize_in(0, 200) {
                let u = g.u64_below(n as u64) as u32;
                let v = g.u64_below(n as u64) as u32;
                el.push(u, v, g.f64().max(1e-9));
            }
            let (once, _) = preprocess(&el);
            assert!(is_simple(&once));
            let (twice, stats2) = preprocess(&once);
            assert_eq!(stats2.self_loops_removed, 0);
            assert_eq!(stats2.multi_edges_removed, 0);
            assert_eq!(twice.n_edges(), once.n_edges());
        });
    }

    #[test]
    fn stats_add_up() {
        props("preprocess stats conserve edges", 100, |g| {
            let n = g.usize_in(2, 30) as u32;
            let mut el = EdgeList::with_vertices(n);
            for _ in 0..g.usize_in(0, 100) {
                let u = g.u64_below(n as u64) as u32;
                let v = g.u64_below(n as u64) as u32;
                el.push(u, v, g.f64().max(1e-9));
            }
            let (_, s) = preprocess(&el);
            assert_eq!(
                s.edges_kept + s.self_loops_removed + s.multi_edges_removed,
                el.n_edges()
            );
        });
    }
}
