//! Graph substrate: weighted edge lists, CRS storage, generators,
//! preprocessing, partitioning and I/O.
//!
//! Vertices are `u32` (the paper's "vertex identifier is a 32 bit machine
//! word"); weights are `f64` in the open interval (0, 1) extended with a
//! `special_id` tiebreak so all weights are distinct (paper §3.2).

pub mod connectivity;
pub mod csr;
pub mod generators;
pub mod io;
pub mod partition;
pub mod preprocess;

use crate::ghs::weight::EdgeWeight;

/// Vertex identifier (paper: 32-bit machine word).
pub type VertexId = u32;

/// A single weighted undirected edge. `u != v` after preprocessing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightedEdge {
    pub u: VertexId,
    pub v: VertexId,
    pub w: f64,
}

impl WeightedEdge {
    /// Construct an edge.
    pub fn new(u: VertexId, v: VertexId, w: f64) -> Self {
        Self { u, v, w }
    }

    /// The GHS-unique weight of this edge: raw weight + `special_id`
    /// tiebreak derived from the endpoint pair (paper §3.2).
    pub fn unique_weight(&self) -> EdgeWeight {
        EdgeWeight::new(self.w, self.u, self.v)
    }

    /// Canonical endpoint ordering `(min, max)`.
    pub fn canonical(&self) -> (VertexId, VertexId) {
        (self.u.min(self.v), self.u.max(self.v))
    }
}

/// An undirected weighted graph as an edge list plus vertex count.
#[derive(Debug, Clone, Default)]
pub struct EdgeList {
    /// Number of vertices; vertex ids are `0..n_vertices`.
    pub n_vertices: u32,
    /// Undirected edges (each stored once, in either orientation).
    pub edges: Vec<WeightedEdge>,
}

impl EdgeList {
    /// Empty graph with `n` vertices.
    pub fn with_vertices(n: u32) -> Self {
        Self { n_vertices: n, edges: Vec::new() }
    }

    /// Add an undirected edge.
    pub fn push(&mut self, u: VertexId, v: VertexId, w: f64) {
        debug_assert!(u < self.n_vertices && v < self.n_vertices);
        self.edges.push(WeightedEdge::new(u, v, w));
    }

    /// Number of edges.
    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Total weight of all edges.
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.w).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_canonicalization() {
        let e = WeightedEdge::new(5, 2, 0.5);
        assert_eq!(e.canonical(), (2, 5));
        let e2 = WeightedEdge::new(2, 5, 0.5);
        assert_eq!(e.unique_weight(), e2.unique_weight());
    }

    #[test]
    fn edge_list_basics() {
        let mut g = EdgeList::with_vertices(4);
        g.push(0, 1, 0.25);
        g.push(1, 2, 0.5);
        assert_eq!(g.n_edges(), 2);
        assert!((g.total_weight() - 0.75).abs() < 1e-12);
    }
}
