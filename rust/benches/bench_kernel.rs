//! Bench: the L1/runtime micro-benchmark — throughput of the AOT Pallas
//! min-edge kernel through PJRT vs a scalar Rust reduction, plus the
//! bytes-touched roofline estimate recorded in EXPERIMENTS.md §Perf.
//! Run: `make artifacts && cargo bench --bench bench_kernel`

use std::time::Instant;

use ghs_mst::coordinator::report::Table;
use ghs_mst::runtime::minedge::MinEdgeExecutable;
use ghs_mst::runtime::Runtime;
use ghs_mst::util::prng::Xoshiro256;

fn scalar_minedge(frag: &[i32], nbrf: &[i32], w: &[f32], k: usize, bw: &mut [f32], bi: &mut [i32]) {
    for (r, f) in frag.iter().enumerate() {
        let (mut best, mut idx) = (f32::INFINITY, 0i32);
        for s in 0..k {
            let j = r * k + s;
            if nbrf[j] != *f && w[j] < best {
                best = w[j];
                idx = s as i32;
            }
        }
        bw[r] = best;
        bi[r] = idx;
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let mut t = Table::new(
        "Kernel micro-benchmark — PJRT minedge vs scalar Rust",
        &["Block", "Reps", "Device ms/block", "Scalar ms/block", "Device Mrows/s", "GB/s touched"],
    );
    for (b, k, reps) in [(128usize, 16usize, 50u32), (4096, 32, 20)] {
        let exe = MinEdgeExecutable::load(&rt, b, k)?;
        let mut rng = Xoshiro256::seed_from_u64(1);
        let frag: Vec<i32> = (0..b).map(|_| rng.next_below(64) as i32).collect();
        let nbrf: Vec<i32> = (0..b * k).map(|_| rng.next_below(64) as i32).collect();
        let w: Vec<f32> = (0..b * k).map(|i| i as f32).collect();
        // Warm-up (compile caches, first-touch).
        exe.run(&frag, &nbrf, &w)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            exe.run(&frag, &nbrf, &w)?;
        }
        let dev = t0.elapsed().as_secs_f64() / reps as f64;
        let (mut bw, mut bi) = (vec![0f32; b], vec![0i32; b]);
        let t0 = Instant::now();
        for _ in 0..reps {
            scalar_minedge(&frag, &nbrf, &w, k, &mut bw, &mut bi);
        }
        let scalar = t0.elapsed().as_secs_f64() / reps as f64;
        let bytes = (b * k * 8 + b * 4) as f64; // nbrf + w read, frag re-read
        t.push_row(vec![
            format!("{b}x{k}"),
            reps.to_string(),
            format!("{:.3}", dev * 1e3),
            format!("{:.3}", scalar * 1e3),
            format!("{:.2}", b as f64 / dev / 1e6),
            format!("{:.2}", bytes / dev / 1e9),
        ]);
    }
    t.note(
        "interpret-mode Pallas on the CPU PJRT client measures dispatch + reduction, not TPU \
         perf; DESIGN.md §Hardware-Adaptation estimates VMEM/VPU roofline for real hardware.",
    );
    println!("{}", t.to_markdown());
    let p = t.write("kernel_bench")?;
    eprintln!("[bench_kernel] wrote {p:?}");
    Ok(())
}
