//! Bench: regenerate paper Fig 3 (profile breakdown of the process loop,
//! hash-only version vs final version).
//! Run: `cargo bench --bench bench_fig3`

use ghs_mst::coordinator::experiments::{fig3, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions::default();
    eprintln!("[bench_fig3] scale {}", opts.scale);
    let t = fig3(&opts)?;
    println!("{}", t.to_markdown());
    let p = t.write("fig3")?;
    eprintln!("[bench_fig3] wrote {p:?}");
    Ok(())
}
