//! Bench: regenerate paper Fig 5 (weak scaling: growing RMAT scales on a
//! fixed 32 nodes / 256 ranks).
//! Run: `cargo bench --bench bench_fig5`

use ghs_mst::coordinator::experiments::{fig5, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions::default();
    eprintln!("[bench_fig5] scales {}..={}", opts.scale.saturating_sub(4).max(8), opts.scale);
    let t = fig5(&opts)?;
    println!("{}", t.to_markdown());
    let p = t.write("fig5")?;
    eprintln!("[bench_fig5] wrote {p:?}");
    Ok(())
}
