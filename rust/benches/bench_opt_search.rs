//! Bench: regenerate the paper's §4.1 study (linear vs binary vs hash
//! local-edge search on one node; paper: -2 % / -18 %).
//! Run: `cargo bench --bench bench_opt_search`

use ghs_mst::coordinator::experiments::{sweep_search, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions::default();
    eprintln!("[bench_opt_search] scale {}", opts.scale);
    let t = sweep_search(&opts)?;
    println!("{}", t.to_markdown());
    let p = t.write("sweep_search")?;
    eprintln!("[bench_opt_search] wrote {p:?}");
    Ok(())
}
