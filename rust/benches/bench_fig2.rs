//! Bench: regenerate paper Fig 2a/2b (runtime and scaling as the
//! optimizations stack: base -> +hash -> +hash+test-queue -> final).
//! Run: `cargo bench --bench bench_fig2`

use ghs_mst::coordinator::experiments::{ablation_test_queue, fig2, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions::default();
    eprintln!("[bench_fig2] scale {} max_nodes {}", opts.scale, opts.max_nodes);
    let (a, b) = fig2(&opts)?;
    println!("{}", a.to_markdown());
    println!("{}", b.to_markdown());
    a.write("fig2a")?;
    let p = b.write("fig2b")?;
    // The §3.4 mechanism behind Fig 2b's 2x-scaling claim, shown where the
    // postponed-Test churn actually builds up at this scale.
    let abl = ablation_test_queue(&opts)?;
    println!("{}", abl.to_markdown());
    abl.write("ablation_test_queue")?;
    eprintln!("[bench_fig2] wrote {p:?} (+fig2a, +ablation_test_queue)");
    Ok(())
}
