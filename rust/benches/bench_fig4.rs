//! Bench: regenerate paper Fig 4 (average aggregated message size per
//! execution-time interval for several node counts, MAX_MSG_SIZE=20000).
//! Run: `cargo bench --bench bench_fig4`

use ghs_mst::coordinator::experiments::{fig4, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions::default();
    eprintln!("[bench_fig4] scale {}", opts.scale);
    let t = fig4(&opts)?;
    println!("{}", t.to_markdown());
    let p = t.write("fig4")?;
    eprintln!("[bench_fig4] wrote {p:?}");
    Ok(())
}
