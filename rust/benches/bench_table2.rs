//! Bench: regenerate paper Table 2 (strong scaling of the final version on
//! RMAT / SSCA2 / Random). `GHS_SCALE` / `GHS_MAX_NODES` override the
//! laptop-sized defaults.
//! Run: `cargo bench --bench bench_table2`

use ghs_mst::coordinator::experiments::{table2, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions::default();
    eprintln!("[bench_table2] scale {} max_nodes {}", opts.scale, opts.max_nodes);
    let t = table2(&opts)?;
    println!("{}", t.to_markdown());
    let p = t.write("table2")?;
    eprintln!("[bench_table2] wrote {p:?}");
    Ok(())
}
