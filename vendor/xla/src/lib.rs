//! API-only offline stub of the `xla` crate (the xla-rs PJRT bindings).
//!
//! The real crate links `xla_extension` (a PJRT shared library) and cannot
//! be vendored offline, so this stub mirrors the exact type/function surface
//! that `ghs_mst::runtime` compiles against:
//!
//! * [`PjRtClient::cpu`] succeeds (so client creation and artifact-path
//!   diagnostics behave), but
//! * everything that would touch a real device — HLO parsing, compilation,
//!   execution, literal transfer — returns [`Error`] with an actionable
//!   message.
//!
//! Result: `cargo build/test --features accelerate` compiles and degrades
//! gracefully when no PJRT backend is installed. To execute AOT artifacts
//! for real, replace the `xla = { path = "../vendor/xla" }` entry in
//! `rust/Cargo.toml` with the crates.io `xla` crate.

use std::fmt;

/// Stub error: carries the reason an operation is unavailable.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Self {
            msg: format!(
                "xla stub: {what} is unavailable — this workspace vendors an API-only stub of \
                 the `xla` crate; swap in the real xla-rs crate (plus its PJRT shared library) \
                 to execute HLO artifacts"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching xla-rs.
pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub "CPU client" can be created (cheap, no
/// device), which lets host code run its artifact-existence diagnostics.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the (stub) CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform name reported by the client.
    pub fn platform_name(&self) -> String {
        "stub-cpu (no PJRT backend)".to_string()
    }

    /// Compile a computation — unavailable in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("XLA compilation"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — unavailable in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals — unavailable in the stub.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execution"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal — unavailable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("device-to-host transfer"))
    }
}

/// A host-side literal (typed multidimensional array).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice (shape-only in the stub).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape (shape bookkeeping only in the stub).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Split a 2-tuple literal — unavailable in the stub.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("tuple literal decomposition"))
    }

    /// Copy out as a typed vector — unavailable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("literal readback"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creates_but_device_paths_error() {
        let client = PjRtClient::cpu().unwrap();
        assert!(!client.platform_name().is_empty());
        let comp = XlaComputation::from_proto(&HloModuleProto { _private: () });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn literals_shape_ops_work_without_device() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        let r = l.reshape(&[3, 1]).unwrap();
        assert!(r.to_vec::<i32>().is_err());
        assert!(r.to_tuple2().is_err());
    }
}
