//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The workspace builds fully offline (no crates.io), so this crate vendors
//! exactly the subset of the anyhow 1.x API that `ghs_mst` uses:
//!
//! * [`Error`] — an erased error with a display message and optional source
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`
//! * [`anyhow!`], [`bail!`], [`ensure!`] — message/early-return macros
//!
//! Semantics match anyhow where it matters for this workspace: `?` converts
//! any `E: std::error::Error + Send + Sync + 'static`, `Display` shows the
//! outermost message, and `Debug` (what `fn main() -> Result<()>` prints)
//! additionally shows the captured source. Swapping in the real crate is a
//! one-line change in `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// An erased error: owned message plus an optional captured source error.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Create an error from a standard error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap this error with higher-level context. The context becomes the
    /// leading part of the display message; the original source is kept.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The captured source error, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion coherent
// next to core's reflexive `From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result<T, anyhow::Error>` with a defaulted error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening widget").unwrap_err();
        assert!(e.to_string().starts_with("opening widget"));
        assert!(e.to_string().contains("missing thing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value for {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "no value for 7");
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("inline {n}");
        assert_eq!(e.to_string(), "inline 3");
        let e = anyhow!("positional {} and {}", 1, 2);
        assert_eq!(e.to_string(), "positional 1 and 2");

        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            ensure!(1 + 1 == 2);
            ensure!(!flag, "ensure with {} args", 1);
            Ok(9)
        }
        assert_eq!(f(false).unwrap(), 9);
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
    }
}
